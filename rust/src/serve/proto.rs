//! The `mlu serve` **wire protocol**: a small versioned length-prefixed
//! binary framing for factor/solve requests and typed responses /
//! rejections, spoken over TCP and Unix sockets.
//!
//! **The normative byte-level specification is DESIGN.md §14** — the
//! tables there and the encoders/decoders here must match byte for
//! byte; the protocol unit tests pin representative frames against
//! hand-written byte images to keep them honest. Summary:
//!
//! ```text
//! frame   := header payload
//! header  := magic(2 = "ML") version(1) type(1) id(8 LE) len(4 LE)
//! payload := `len` bytes, layout per frame type (DESIGN.md §14)
//! ```
//!
//! All integers are **little-endian**; all floating-point data is
//! IEEE-754 binary32/binary64, little-endian, **column-major** for
//! matrices. The `id` is assigned by the client, unique per connection,
//! and echoed verbatim in the matching response or rejection — so a
//! client may pipeline requests and match responses in any completion
//! order.
//!
//! This module is pure encode/decode over byte slices plus one
//! incremental frame reader ([`read_frame`]); it performs no admission
//! decisions and owns no sockets. The daemon lives in
//! [`crate::serve::net`], the client in [`crate::serve::client`], and
//! admission control in [`crate::serve::admission`].

use crate::factor::{FactorError, FactorKind};
use crate::matrix::{Mat, Matrix};
use crate::solve::SolvePrec;
use std::io::Read;

/// Frame magic, bytes 0–1 of every header: ASCII `"ML"`.
pub const MAGIC: [u8; 2] = *b"ML";
/// The one protocol version this build speaks (header byte 2).
pub const VERSION: u8 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 16;

/// Frame type: client hello (version negotiation), `id = 0`.
pub const T_HELLO: u8 = 0x01;
/// Frame type: server hello acknowledgement, `id = 0`.
pub const T_HELLO_ACK: u8 = 0x02;
/// Frame type: factorization request (client → server).
pub const T_FACTOR: u8 = 0x10;
/// Frame type: linear-system solve request (client → server).
pub const T_SOLVE: u8 = 0x11;
/// Frame type: factorization response (server → client).
pub const T_FACTOR_OK: u8 = 0x20;
/// Frame type: solve response (server → client).
pub const T_SOLVE_OK: u8 = 0x21;
/// Frame type: typed rejection (server → client).
pub const T_REJECT: u8 = 0x30;
/// Frame type: typed failure of an *admitted* request (server →
/// client). Distinct from [`T_REJECT`]: the request passed admission
/// and ran, but the computation itself failed — the matrix is exactly
/// singular, the payload carries NaNs, or the daemon suffered an
/// internal fault while executing it.
pub const T_FAILED: u8 = 0x31;
/// Frame type: client goodbye — flush and close, `id = 0`, empty payload.
pub const T_GOODBYE: u8 = 0x40;

/// One decoded frame: type byte, request id, raw payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Frame type (`T_*` constant).
    pub ty: u8,
    /// Request id (0 for session-level frames).
    pub id: u64,
    /// Raw payload bytes (layout per type; DESIGN.md §14).
    pub payload: Vec<u8>,
}

/// Why a request (or a whole connection) was refused — the typed
/// rejection codes of DESIGN.md §14. Encoded as payload byte 0 of a
/// [`T_REJECT`] frame.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum RejectCode {
    /// The admission queue (global bound or this client's fairness
    /// quota) is full; retry later. Code 1.
    Overloaded = 1,
    /// The frame or problem exceeds the daemon's configured size bounds
    /// (`max_frame` payload bytes or `max_dim` matrix dimension). Code 2.
    TooLarge = 2,
    /// The daemon is draining toward shutdown and admits no new work.
    /// Code 3.
    Draining = 3,
    /// The frame could not be decoded (bad magic, unknown type,
    /// inconsistent lengths, bad enum codes). Code 4.
    Malformed = 4,
    /// Version negotiation failed: the server speaks no version in the
    /// client's offered range. Code 5.
    Unsupported = 5,
}

impl RejectCode {
    /// Wire code byte.
    pub fn code(self) -> u8 {
        self as u8
    }

    /// Decode a wire code byte.
    pub fn parse(c: u8) -> Option<Self> {
        match c {
            1 => Some(Self::Overloaded),
            2 => Some(Self::TooLarge),
            3 => Some(Self::Draining),
            4 => Some(Self::Malformed),
            5 => Some(Self::Unsupported),
            _ => None,
        }
    }

    /// Human-readable name (logs, `mlu sclient` output).
    pub fn name(self) -> &'static str {
        match self {
            Self::Overloaded => "overloaded",
            Self::TooLarge => "too-large",
            Self::Draining => "draining",
            Self::Malformed => "malformed",
            Self::Unsupported => "unsupported",
        }
    }
}

/// A decoded rejection frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reject {
    /// Why the request was refused.
    pub code: RejectCode,
    /// Free-form operator-facing reason (UTF-8; may be empty).
    pub reason: String,
}

/// Why an admitted request failed — payload byte 0 of a [`T_FAILED`]
/// frame, mirroring [`FactorError`]'s wire encoding.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum FailCode {
    /// The matrix is exactly singular; `detail` carries the first
    /// offending column. Numerical, not retryable. Code 1.
    Singular = 1,
    /// The input (or the working-precision arithmetic) holds a
    /// non-finite value; `detail` carries the column-major offset of
    /// the first offender. Numerical, not retryable. Code 2.
    NonFinite = 2,
    /// The request is structurally unsupported for the chosen
    /// factorization (e.g. not positive definite for Cholesky).
    /// Numerical, not retryable. Code 3.
    Unsupported = 3,
    /// A daemon-side fault while executing the request (worker panic,
    /// poisoned crew, watchdog cancellation). The input may be fine —
    /// retrying is reasonable. Code 4.
    Internal = 4,
}

impl FailCode {
    /// Wire code byte.
    pub fn code(self) -> u8 {
        self as u8
    }

    /// Decode a wire code byte.
    pub fn parse(c: u8) -> Option<Self> {
        match c {
            1 => Some(Self::Singular),
            2 => Some(Self::NonFinite),
            3 => Some(Self::Unsupported),
            4 => Some(Self::Internal),
            _ => None,
        }
    }

    /// Human-readable name (logs, `mlu sclient` output).
    pub fn name(self) -> &'static str {
        match self {
            Self::Singular => "singular",
            Self::NonFinite => "non-finite",
            Self::Unsupported => "unsupported",
            Self::Internal => "internal",
        }
    }
}

/// A decoded failure frame ([`T_FAILED`] payload).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Failure {
    /// Failure class (drives client-side retry decisions).
    pub code: FailCode,
    /// Class-specific detail: offending column for [`FailCode::Singular`],
    /// column-major offset for [`FailCode::NonFinite`], 0 otherwise.
    pub detail: u64,
    /// Operator-facing description (UTF-8; the [`FactorError`] display
    /// string on the server side).
    pub reason: String,
}

impl Failure {
    /// Build the wire failure for a typed factorization error.
    pub fn from_error(e: &FactorError) -> Self {
        let code = match e {
            FactorError::ExactlySingular { .. } => FailCode::Singular,
            FactorError::NonFinite { .. } => FailCode::NonFinite,
            FactorError::Unsupported(_) => FailCode::Unsupported,
            FactorError::Internal(_) => FailCode::Internal,
        };
        Self {
            code,
            detail: e.wire_detail(),
            reason: e.to_string(),
        }
    }
}

/// Matrix payload in either wire precision (prec byte 0 = f64,
/// 1 = f32).
#[derive(Debug, Clone)]
pub enum WireMat {
    /// Double precision (8-byte elements).
    F64(Mat<f64>),
    /// Single precision (4-byte elements).
    F32(Mat<f32>),
}

impl WireMat {
    /// Wire precision code (0 = f64, 1 = f32).
    pub fn prec_code(&self) -> u8 {
        match self {
            Self::F64(_) => 0,
            Self::F32(_) => 1,
        }
    }

    /// Precision name as used in trace tags ("f64" / "f32").
    pub fn prec_name(&self) -> &'static str {
        match self {
            Self::F64(_) => "f64",
            Self::F32(_) => "f32",
        }
    }

    /// Rows of the carried matrix.
    pub fn rows(&self) -> usize {
        match self {
            Self::F64(a) => a.rows(),
            Self::F32(a) => a.rows(),
        }
    }

    /// Columns of the carried matrix.
    pub fn cols(&self) -> usize {
        match self {
            Self::F64(a) => a.cols(),
            Self::F32(a) => a.cols(),
        }
    }
}

/// A vector payload matching a [`WireMat`]'s precision (QR `tau`).
#[derive(Debug, Clone)]
pub enum WireVec {
    /// Double-precision elements.
    F64(Vec<f64>),
    /// Single-precision elements.
    F32(Vec<f32>),
}

impl WireVec {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Self::F64(v) => v.len(),
            Self::F32(v) => v.len(),
        }
    }

    /// Whether the vector is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A decoded factorization request ([`T_FACTOR`] payload).
#[derive(Debug, Clone)]
pub struct FactorReq {
    /// Which factorization to run.
    pub kind: FactorKind,
    /// Scheduling priority (higher runs first).
    pub priority: u8,
    /// Wall-clock budget in milliseconds; 0 = none.
    pub deadline_ms: u32,
    /// Outer block-size override; 0 = server default.
    pub bo: u16,
    /// Inner block-size override; 0 = server default.
    pub bi: u16,
    /// The matrix, in its wire precision.
    pub a: WireMat,
}

/// A decoded factorization response ([`T_FACTOR_OK`] payload).
#[derive(Debug, Clone)]
pub struct FactorResp {
    /// The factorization that ran.
    pub kind: FactorKind,
    /// Whether the request was cancelled (deadline / drain ET); the
    /// factors then hold a clean `cols_done`-column prefix.
    pub cancelled: bool,
    /// Columns fully factorized and committed.
    pub cols_done: usize,
    /// Server-side seconds from admission to completion.
    pub secs: f64,
    /// Absolute pivots for the committed columns (LU only).
    pub ipiv: Vec<u32>,
    /// Householder scalar factors (QR only), in the matrix precision.
    pub tau: WireVec,
    /// The factors, in the request's precision.
    pub a: WireMat,
}

/// A decoded solve request ([`T_SOLVE`] payload). The system is always
/// shipped in f64; `prec` selects the factorization arithmetic
/// (mixed = f32 factors + f64 refinement, DESIGN.md §12).
#[derive(Debug, Clone)]
pub struct SolveReq {
    /// Which arithmetic the solve runs in.
    pub prec: SolvePrec,
    /// Scheduling priority (higher runs first).
    pub priority: u8,
    /// Wall-clock budget in milliseconds; 0 = none.
    pub deadline_ms: u32,
    /// Outer block-size override; 0 = server default.
    pub bo: u16,
    /// Inner block-size override; 0 = server default.
    pub bi: u16,
    /// The (square) system matrix.
    pub a: Matrix,
    /// The right-hand side (`b.len() == a.rows()`).
    pub b: Vec<f64>,
}

/// A decoded solve response ([`T_SOLVE_OK`] payload).
#[derive(Debug, Clone)]
pub struct SolveResp {
    /// The arithmetic that ran.
    pub prec: SolvePrec,
    /// Whether the request was cancelled before completion.
    pub cancelled: bool,
    /// Whether the precision path's convergence criterion was met.
    pub converged: bool,
    /// Refinement sweeps performed (mixed path only).
    pub refine_iters: u32,
    /// Final normwise backward error.
    pub backward_error: f64,
    /// Server-side seconds from admission to completion.
    pub secs: f64,
    /// The solution (empty if cancelled).
    pub x: Vec<f64>,
}

/// Decode failure: the frame was well-delimited but its payload does
/// not parse (wrong length, bad enum code, overflowing dimensions).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError(pub String);

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "protocol error: {}", self.0)
    }
}

impl std::error::Error for ProtoError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ProtoError> {
    Err(ProtoError(msg.into()))
}

// ---------------------------------------------------------------------------
// Little-endian primitives.

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.i + n > self.b.len() {
            return err(format!(
                "truncated payload: need {} bytes at offset {}, have {}",
                n,
                self.i,
                self.b.len() - self.i
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ProtoError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f64_vec(&mut self, n: usize) -> Result<Vec<f64>, ProtoError> {
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            })
            .collect())
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, ProtoError> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn done(&self) -> Result<(), ProtoError> {
        if self.i != self.b.len() {
            return err(format!(
                "{} trailing bytes after payload",
                self.b.len() - self.i
            ));
        }
        Ok(())
    }
}

fn put_f64_slice(out: &mut Vec<u8>, v: &[f64]) {
    out.reserve(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f32_slice(out: &mut Vec<u8>, v: &[f32]) {
    out.reserve(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn kind_code(kind: FactorKind) -> u8 {
    match kind {
        FactorKind::Lu => 0,
        FactorKind::Chol => 1,
        FactorKind::Qr => 2,
    }
}

fn parse_kind(c: u8) -> Result<FactorKind, ProtoError> {
    match c {
        0 => Ok(FactorKind::Lu),
        1 => Ok(FactorKind::Chol),
        2 => Ok(FactorKind::Qr),
        other => err(format!("unknown factor kind code {other}")),
    }
}

fn solve_prec_code(p: SolvePrec) -> u8 {
    match p {
        SolvePrec::F64 => 0,
        SolvePrec::F32 => 1,
        SolvePrec::Mixed => 2,
    }
}

fn parse_solve_prec(c: u8) -> Result<SolvePrec, ProtoError> {
    match c {
        0 => Ok(SolvePrec::F64),
        1 => Ok(SolvePrec::F32),
        2 => Ok(SolvePrec::Mixed),
        other => err(format!("unknown solve precision code {other}")),
    }
}

/// Checked `m * n * elem_size` for payload sizing; rejects dimension
/// products that overflow or exceed `u32::MAX` payload bytes.
fn data_bytes(m: usize, n: usize, elem: usize) -> Result<usize, ProtoError> {
    m.checked_mul(n)
        .and_then(|e| e.checked_mul(elem))
        .filter(|&b| b <= u32::MAX as usize)
        .ok_or_else(|| ProtoError(format!("matrix {m}x{n} overflows the frame length field")))
}

// ---------------------------------------------------------------------------
// Frame assembly and header parsing.

/// Assemble a full frame (header + payload) for `ty`/`id`.
pub fn encode_frame(ty: u8, id: u64, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= u32::MAX as usize, "payload exceeds u32 length");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(ty);
    put_u64(&mut out, id);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

/// Parsed header fields: `(type, id, payload_len)`.
pub fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(u8, u64, u32), ProtoError> {
    if h[0..2] != MAGIC {
        return err(format!("bad magic {:02x}{:02x} (want 4d4c)", h[0], h[1]));
    }
    if h[2] != VERSION {
        return err(format!("unsupported protocol version {} (want {VERSION})", h[2]));
    }
    let ty = h[3];
    let id = u64::from_le_bytes([h[4], h[5], h[6], h[7], h[8], h[9], h[10], h[11]]);
    let len = u32::from_le_bytes([h[12], h[13], h[14], h[15]]);
    Ok((ty, id, len))
}

/// What [`read_frame`] observed on the stream.
#[derive(Debug)]
pub enum ReadEvent {
    /// A complete frame (payload already bounded by `max_payload`).
    Frame(Frame),
    /// Clean end-of-stream at a frame boundary.
    Eof,
    /// The tick callback asked to stop while no partial frame was
    /// buffered (idle close point).
    Closed,
    /// A frame header announced a payload above `max_payload`; the
    /// payload was drained and discarded. Carries `(id, announced_len)`
    /// so the caller can send a typed `TooLarge` rejection.
    Oversized(u64, u32),
    /// The header failed to parse (bad magic / version) or the stream
    /// died mid-frame. The connection is unusable for further framing.
    Corrupt(ProtoError),
}

/// Read one frame from `r`, tolerating read timeouts.
///
/// `tick` is called after every timed-out read with `idle = true` when
/// no byte of the next frame has arrived yet; returning `false` stops
/// the read. Stopping while idle yields [`ReadEvent::Closed`]; stopping
/// mid-frame (or hitting EOF mid-frame) yields [`ReadEvent::Corrupt`],
/// because the framing can no longer be trusted.
pub fn read_frame(
    r: &mut impl Read,
    max_payload: usize,
    tick: &mut dyn FnMut(bool) -> bool,
) -> ReadEvent {
    let mut header = [0u8; HEADER_LEN];
    match read_full(r, &mut header, true, tick) {
        Fill::Done => {}
        Fill::Eof { nothing_read: true } => return ReadEvent::Eof,
        Fill::Eof { nothing_read: false } => {
            return ReadEvent::Corrupt(ProtoError("eof inside a frame header".into()))
        }
        Fill::Stopped { nothing_read: true } => return ReadEvent::Closed,
        Fill::Stopped { nothing_read: false } => {
            return ReadEvent::Corrupt(ProtoError("stopped inside a frame header".into()))
        }
        Fill::Io(e) => return ReadEvent::Corrupt(ProtoError(format!("read: {e}"))),
    }
    let (ty, id, len) = match parse_header(&header) {
        Ok(t) => t,
        Err(e) => return ReadEvent::Corrupt(e),
    };
    if len as usize > max_payload {
        // Drain without buffering so the connection stays framed.
        let mut left = len as usize;
        let mut sink = [0u8; 4096];
        while left > 0 {
            let want = left.min(sink.len());
            match read_full(r, &mut sink[..want], false, tick) {
                Fill::Done => left -= want,
                _ => {
                    return ReadEvent::Corrupt(ProtoError(
                        "stream died while draining an oversized frame".into(),
                    ))
                }
            }
        }
        return ReadEvent::Oversized(id, len);
    }
    let mut payload = vec![0u8; len as usize];
    match read_full(r, &mut payload, false, tick) {
        Fill::Done => ReadEvent::Frame(Frame { ty, id, payload }),
        Fill::Io(e) => ReadEvent::Corrupt(ProtoError(format!("read: {e}"))),
        _ => ReadEvent::Corrupt(ProtoError("eof inside a frame payload".into())),
    }
}

enum Fill {
    Done,
    Eof { nothing_read: bool },
    Stopped { nothing_read: bool },
    Io(std::io::Error),
}

/// `read_exact` that survives read timeouts: partial progress is kept
/// across timed-out reads (plain `read_exact` would lose it and corrupt
/// the framing).
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    at_boundary: bool,
    tick: &mut dyn FnMut(bool) -> bool,
) -> Fill {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return Fill::Eof { nothing_read: at_boundary && got == 0 },
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if !tick(at_boundary && got == 0) {
                    return Fill::Stopped { nothing_read: at_boundary && got == 0 };
                }
            }
            Err(e) => return Fill::Io(e),
        }
    }
    Fill::Done
}

// ---------------------------------------------------------------------------
// Session frames.

/// Encode the client hello: offered version range `[min, max]`.
pub fn encode_hello(min_ver: u8, max_ver: u8) -> Vec<u8> {
    encode_frame(T_HELLO, 0, &[min_ver, max_ver])
}

/// Decode a hello payload into `(min_ver, max_ver)`.
pub fn decode_hello(p: &[u8]) -> Result<(u8, u8), ProtoError> {
    if p.len() != 2 {
        return err(format!("hello payload must be 2 bytes, got {}", p.len()));
    }
    Ok((p[0], p[1]))
}

/// Encode the server's hello acknowledgement carrying the chosen
/// version.
pub fn encode_hello_ack(version: u8) -> Vec<u8> {
    encode_frame(T_HELLO_ACK, 0, &[version])
}

/// Decode a hello-ack payload into the chosen version.
pub fn decode_hello_ack(p: &[u8]) -> Result<u8, ProtoError> {
    if p.len() != 1 {
        return err(format!("hello-ack payload must be 1 byte, got {}", p.len()));
    }
    Ok(p[0])
}

/// Encode the client goodbye (flush-and-close).
pub fn encode_goodbye() -> Vec<u8> {
    encode_frame(T_GOODBYE, 0, &[])
}

/// Encode a typed rejection for request `id`.
pub fn encode_reject(id: u64, code: RejectCode, reason: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(4 + reason.len());
    p.push(code.code());
    p.extend_from_slice(&[0, 0, 0]);
    p.extend_from_slice(reason.as_bytes());
    encode_frame(T_REJECT, id, &p)
}

/// Decode a rejection payload.
pub fn decode_reject(p: &[u8]) -> Result<Reject, ProtoError> {
    let mut c = Cursor::new(p);
    let code = c.u8()?;
    c.take(3)?;
    let code = RejectCode::parse(code).ok_or_else(|| ProtoError(format!("bad reject code {code}")))?;
    let reason = String::from_utf8_lossy(&p[4..]).into_owned();
    Ok(Reject { code, reason })
}

/// Encode a typed failure for admitted request `id`. Payload layout
/// (DESIGN.md §14): `code(1) reserved(3) detail(8 LE) reason(UTF-8,
/// rest of payload)`.
pub fn encode_failed(id: u64, f: &Failure) -> Vec<u8> {
    let mut p = Vec::with_capacity(12 + f.reason.len());
    p.push(f.code.code());
    p.extend_from_slice(&[0, 0, 0]);
    put_u64(&mut p, f.detail);
    p.extend_from_slice(f.reason.as_bytes());
    encode_frame(T_FAILED, id, &p)
}

/// Decode a failure payload.
pub fn decode_failed(p: &[u8]) -> Result<Failure, ProtoError> {
    let mut c = Cursor::new(p);
    let code = c.u8()?;
    c.take(3)?;
    let detail = c.u64()?;
    let code =
        FailCode::parse(code).ok_or_else(|| ProtoError(format!("bad failure code {code}")))?;
    let reason = String::from_utf8_lossy(&p[12..]).into_owned();
    Ok(Failure { code, detail, reason })
}

// ---------------------------------------------------------------------------
// Factor request/response.

/// Fixed (pre-data) bytes of a [`T_FACTOR`] payload.
pub const FACTOR_REQ_FIXED: usize = 20;

/// Encode a factorization request frame.
pub fn encode_factor_req(id: u64, req: &FactorReq) -> Vec<u8> {
    let (m, n) = (req.a.rows(), req.a.cols());
    let mut p = Vec::with_capacity(FACTOR_REQ_FIXED);
    p.push(kind_code(req.kind));
    p.push(req.a.prec_code());
    p.push(req.priority);
    p.push(0);
    put_u32(&mut p, m as u32);
    put_u32(&mut p, n as u32);
    put_u32(&mut p, req.deadline_ms);
    put_u16(&mut p, req.bo);
    put_u16(&mut p, req.bi);
    match &req.a {
        WireMat::F64(a) => put_f64_slice(&mut p, a.data()),
        WireMat::F32(a) => put_f32_slice(&mut p, a.data()),
    }
    encode_frame(T_FACTOR, id, &p)
}

/// Decode a factorization request payload.
pub fn decode_factor_req(p: &[u8]) -> Result<FactorReq, ProtoError> {
    let mut c = Cursor::new(p);
    let kind = parse_kind(c.u8()?)?;
    let prec = c.u8()?;
    let priority = c.u8()?;
    c.u8()?; // reserved
    let m = c.u32()? as usize;
    let n = c.u32()? as usize;
    let deadline_ms = c.u32()?;
    let bo = c.u16()?;
    let bi = c.u16()?;
    let a = match prec {
        0 => {
            data_bytes(m, n, 8)?;
            let data = c.f64_vec(m * n)?;
            WireMat::F64(mat_from_col_major(m, n, data))
        }
        1 => {
            data_bytes(m, n, 4)?;
            let data = c.f32_vec(m * n)?;
            WireMat::F32(mat_from_col_major(m, n, data))
        }
        other => return err(format!("unknown matrix precision code {other}")),
    };
    c.done()?;
    Ok(FactorReq { kind, priority, deadline_ms, bo, bi, a })
}

fn mat_from_col_major<S: crate::scalar::Scalar>(m: usize, n: usize, data: Vec<S>) -> Mat<S> {
    let mut a = Mat::<S>::zeros(m, n);
    a.data_mut().copy_from_slice(&data);
    a
}

/// Fixed (pre-data) bytes of a [`T_FACTOR_OK`] payload.
pub const FACTOR_RESP_FIXED: usize = 32;

/// Encode a factorization response frame.
pub fn encode_factor_resp(id: u64, resp: &FactorResp) -> Vec<u8> {
    let (m, n) = (resp.a.rows(), resp.a.cols());
    let mut p = Vec::with_capacity(FACTOR_RESP_FIXED);
    p.push(kind_code(resp.kind));
    p.push(resp.a.prec_code());
    p.push(u8::from(resp.cancelled));
    p.push(0);
    put_u32(&mut p, m as u32);
    put_u32(&mut p, n as u32);
    put_u32(&mut p, resp.cols_done as u32);
    put_u32(&mut p, resp.ipiv.len() as u32);
    put_u32(&mut p, resp.tau.len() as u32);
    put_f64(&mut p, resp.secs);
    for piv in &resp.ipiv {
        put_u32(&mut p, *piv);
    }
    match (&resp.tau, &resp.a) {
        (WireVec::F64(t), WireMat::F64(a)) => {
            put_f64_slice(&mut p, t);
            put_f64_slice(&mut p, a.data());
        }
        (WireVec::F32(t), WireMat::F32(a)) => {
            put_f32_slice(&mut p, t);
            put_f32_slice(&mut p, a.data());
        }
        _ => unreachable!("tau precision always matches the factors"),
    }
    encode_frame(T_FACTOR_OK, id, &p)
}

/// Decode a factorization response payload.
pub fn decode_factor_resp(p: &[u8]) -> Result<FactorResp, ProtoError> {
    let mut c = Cursor::new(p);
    let kind = parse_kind(c.u8()?)?;
    let prec = c.u8()?;
    let cancelled = c.u8()? != 0;
    c.u8()?; // reserved
    let m = c.u32()? as usize;
    let n = c.u32()? as usize;
    let cols_done = c.u32()? as usize;
    let n_ipiv = c.u32()? as usize;
    let n_tau = c.u32()? as usize;
    let secs = c.f64()?;
    let mut ipiv = Vec::with_capacity(n_ipiv);
    for _ in 0..n_ipiv {
        ipiv.push(c.u32()?);
    }
    let (tau, a) = match prec {
        0 => {
            data_bytes(m, n, 8)?;
            let tau = WireVec::F64(c.f64_vec(n_tau)?);
            let a = WireMat::F64(mat_from_col_major(m, n, c.f64_vec(m * n)?));
            (tau, a)
        }
        1 => {
            data_bytes(m, n, 4)?;
            let tau = WireVec::F32(c.f32_vec(n_tau)?);
            let a = WireMat::F32(mat_from_col_major(m, n, c.f32_vec(m * n)?));
            (tau, a)
        }
        other => return err(format!("unknown matrix precision code {other}")),
    };
    c.done()?;
    Ok(FactorResp { kind, cancelled, cols_done, secs, ipiv, tau, a })
}

// ---------------------------------------------------------------------------
// Solve request/response.

/// Fixed (pre-data) bytes of a [`T_SOLVE`] payload.
pub const SOLVE_REQ_FIXED: usize = 16;

/// Encode a solve request frame.
pub fn encode_solve_req(id: u64, req: &SolveReq) -> Vec<u8> {
    let n = req.a.rows();
    let mut p = Vec::with_capacity(SOLVE_REQ_FIXED);
    p.push(solve_prec_code(req.prec));
    p.push(req.priority);
    put_u16(&mut p, 0);
    put_u32(&mut p, n as u32);
    put_u32(&mut p, req.deadline_ms);
    put_u16(&mut p, req.bo);
    put_u16(&mut p, req.bi);
    put_f64_slice(&mut p, req.a.data());
    put_f64_slice(&mut p, &req.b);
    encode_frame(T_SOLVE, id, &p)
}

/// Decode a solve request payload.
pub fn decode_solve_req(p: &[u8]) -> Result<SolveReq, ProtoError> {
    let mut c = Cursor::new(p);
    let prec = parse_solve_prec(c.u8()?)?;
    let priority = c.u8()?;
    c.u16()?; // reserved
    let n = c.u32()? as usize;
    let deadline_ms = c.u32()?;
    let bo = c.u16()?;
    let bi = c.u16()?;
    data_bytes(n, n + 1, 8)?;
    let a = mat_from_col_major(n, n, c.f64_vec(n * n)?);
    let b = c.f64_vec(n)?;
    c.done()?;
    Ok(SolveReq { prec, priority, deadline_ms, bo, bi, a, b })
}

/// Fixed (pre-data) bytes of a [`T_SOLVE_OK`] payload.
pub const SOLVE_RESP_FIXED: usize = 28;

/// Encode a solve response frame.
pub fn encode_solve_resp(id: u64, resp: &SolveResp) -> Vec<u8> {
    let mut p = Vec::with_capacity(SOLVE_RESP_FIXED + resp.x.len() * 8);
    p.push(solve_prec_code(resp.prec));
    p.push(u8::from(resp.cancelled));
    p.push(u8::from(resp.converged));
    p.push(0);
    put_u32(&mut p, resp.x.len() as u32);
    put_u32(&mut p, resp.refine_iters);
    put_f64(&mut p, resp.backward_error);
    put_f64(&mut p, resp.secs);
    put_f64_slice(&mut p, &resp.x);
    encode_frame(T_SOLVE_OK, id, &p)
}

/// Decode a solve response payload.
pub fn decode_solve_resp(p: &[u8]) -> Result<SolveResp, ProtoError> {
    let mut c = Cursor::new(p);
    let prec = parse_solve_prec(c.u8()?)?;
    let cancelled = c.u8()? != 0;
    let converged = c.u8()? != 0;
    c.u8()?; // reserved
    let n_x = c.u32()? as usize;
    let refine_iters = c.u32()?;
    let backward_error = c.f64()?;
    let secs = c.f64()?;
    let x = c.f64_vec(n_x)?;
    c.done()?;
    Ok(SolveResp { prec, cancelled, converged, refine_iters, backward_error, secs, x })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn read_all(bytes: &[u8]) -> ReadEvent {
        let mut r = std::io::Cursor::new(bytes.to_vec());
        read_frame(&mut r, 1 << 20, &mut |_| true)
    }

    #[test]
    fn header_bytes_match_the_spec_table() {
        // DESIGN.md §14: "ML", version 1, type, id LE, len LE.
        let f = encode_frame(T_FACTOR, 0x0102_0304_0506_0708, &[0xAA, 0xBB]);
        assert_eq!(&f[0..2], b"ML");
        assert_eq!(f[2], 1);
        assert_eq!(f[3], 0x10);
        assert_eq!(&f[4..12], &[0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01]);
        assert_eq!(&f[12..16], &[2, 0, 0, 0]);
        assert_eq!(&f[16..], &[0xAA, 0xBB]);
    }

    #[test]
    fn hello_frames_roundtrip_and_match_bytes() {
        let h = encode_hello(1, 1);
        assert_eq!(h.len(), HEADER_LEN + 2);
        assert_eq!(&h[16..], &[1, 1]);
        match read_all(&h) {
            ReadEvent::Frame(f) => {
                assert_eq!(f.ty, T_HELLO);
                assert_eq!(f.id, 0);
                assert_eq!(decode_hello(&f.payload).unwrap(), (1, 1));
            }
            other => panic!("unexpected {other:?}"),
        }
        let ack = encode_hello_ack(1);
        match read_all(&ack) {
            ReadEvent::Frame(f) => assert_eq!(decode_hello_ack(&f.payload).unwrap(), 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn factor_req_roundtrips_both_precisions() {
        let a = Matrix::random(5, 3, 9);
        let req = FactorReq {
            kind: FactorKind::Qr,
            priority: 7,
            deadline_ms: 1234,
            bo: 64,
            bi: 16,
            a: WireMat::F64(a.clone()),
        };
        let frame = encode_factor_req(42, &req);
        // Byte-level spot checks against the §14 table.
        assert_eq!(frame[16], 2, "kind code qr");
        assert_eq!(frame[17], 0, "prec code f64");
        assert_eq!(frame[18], 7, "priority");
        assert_eq!(&frame[20..24], &5u32.to_le_bytes(), "m");
        assert_eq!(&frame[24..28], &3u32.to_le_bytes(), "n");
        assert_eq!(&frame[28..32], &1234u32.to_le_bytes(), "deadline_ms");
        assert_eq!(&frame[32..34], &64u16.to_le_bytes(), "bo");
        assert_eq!(&frame[34..36], &16u16.to_le_bytes(), "bi");
        let got = decode_factor_req(&frame[HEADER_LEN..]).unwrap();
        assert_eq!(got.kind, FactorKind::Qr);
        assert_eq!(got.priority, 7);
        assert_eq!(got.deadline_ms, 1234);
        match got.a {
            WireMat::F64(b) => assert_eq!(b.data(), a.data()),
            _ => panic!("wrong precision"),
        }

        let a32 = Mat::<f32>::random(4, 4, 3);
        let req32 = FactorReq {
            kind: FactorKind::Lu,
            priority: 0,
            deadline_ms: 0,
            bo: 0,
            bi: 0,
            a: WireMat::F32(a32.clone()),
        };
        let frame32 = encode_factor_req(7, &req32);
        assert_eq!(frame32.len(), HEADER_LEN + FACTOR_REQ_FIXED + 16 * 4);
        let got32 = decode_factor_req(&frame32[HEADER_LEN..]).unwrap();
        match got32.a {
            WireMat::F32(b) => assert_eq!(b.data(), a32.data()),
            _ => panic!("wrong precision"),
        }
    }

    #[test]
    fn factor_resp_roundtrips_with_ipiv_and_tau() {
        let f = Matrix::random(4, 4, 1);
        let resp = FactorResp {
            kind: FactorKind::Lu,
            cancelled: false,
            cols_done: 4,
            secs: 0.125,
            ipiv: vec![2, 3, 3, 3],
            tau: WireVec::F64(vec![]),
            a: WireMat::F64(f.clone()),
        };
        let frame = encode_factor_resp(11, &resp);
        let got = decode_factor_resp(&frame[HEADER_LEN..]).unwrap();
        assert_eq!(got.ipiv, vec![2, 3, 3, 3]);
        assert_eq!(got.cols_done, 4);
        assert_eq!(got.secs, 0.125);
        assert!(!got.cancelled);
        match got.a {
            WireMat::F64(b) => assert_eq!(b.data(), f.data()),
            _ => panic!("wrong precision"),
        }
    }

    #[test]
    fn solve_frames_roundtrip() {
        let a = Matrix::random_dd(6, 2);
        let b = vec![1.0; 6];
        let req = SolveReq {
            prec: SolvePrec::Mixed,
            priority: 3,
            deadline_ms: 0,
            bo: 32,
            bi: 8,
            a: a.clone(),
            b: b.clone(),
        };
        let frame = encode_solve_req(5, &req);
        assert_eq!(frame.len(), HEADER_LEN + SOLVE_REQ_FIXED + (36 + 6) * 8);
        let got = decode_solve_req(&frame[HEADER_LEN..]).unwrap();
        assert_eq!(got.prec, SolvePrec::Mixed);
        assert_eq!(got.b, b);
        assert_eq!(got.a.data(), a.data());

        let resp = SolveResp {
            prec: SolvePrec::Mixed,
            cancelled: false,
            converged: true,
            refine_iters: 3,
            backward_error: 1e-16,
            secs: 0.5,
            x: vec![1.0, -2.0, 3.0],
        };
        let frame = encode_solve_resp(5, &resp);
        let got = decode_solve_resp(&frame[HEADER_LEN..]).unwrap();
        assert!(got.converged);
        assert_eq!(got.refine_iters, 3);
        assert_eq!(got.x, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn reject_roundtrips_all_codes() {
        for code in [
            RejectCode::Overloaded,
            RejectCode::TooLarge,
            RejectCode::Draining,
            RejectCode::Malformed,
            RejectCode::Unsupported,
        ] {
            let frame = encode_reject(99, code, "why not");
            match read_all(&frame) {
                ReadEvent::Frame(f) => {
                    assert_eq!(f.ty, T_REJECT);
                    assert_eq!(f.id, 99);
                    let r = decode_reject(&f.payload).unwrap();
                    assert_eq!(r.code, code);
                    assert_eq!(r.reason, "why not");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn failed_frame_matches_spec_bytes_and_roundtrips() {
        // Byte-image pin for the §14 FAILED row: code(1) pad(3)
        // detail(8 LE) reason.
        let f = Failure {
            code: FailCode::Singular,
            detail: 3,
            reason: "zero pivot".into(),
        };
        let frame = encode_failed(21, &f);
        assert_eq!(frame[3], T_FAILED);
        assert_eq!(&frame[4..12], &21u64.to_le_bytes());
        assert_eq!(frame[16], 1, "failure code byte");
        assert_eq!(&frame[17..20], &[0, 0, 0], "reserved pad");
        assert_eq!(&frame[20..28], &3u64.to_le_bytes(), "detail");
        assert_eq!(&frame[28..], b"zero pivot");
        for code in [
            FailCode::Singular,
            FailCode::NonFinite,
            FailCode::Unsupported,
            FailCode::Internal,
        ] {
            let f = Failure {
                code,
                detail: 0xDEAD_BEEF_0102_0304,
                reason: format!("because {}", code.name()),
            };
            let frame = encode_failed(7, &f);
            match read_all(&frame) {
                ReadEvent::Frame(fr) => {
                    assert_eq!(fr.ty, T_FAILED);
                    assert_eq!(fr.id, 7);
                    assert_eq!(decode_failed(&fr.payload).unwrap(), f);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(FailCode::parse(0).is_none());
        assert!(FailCode::parse(5).is_none());
        assert!(decode_failed(&[9, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn failure_from_error_maps_every_variant() {
        let cases = [
            (
                FactorError::ExactlySingular { col: 5 },
                FailCode::Singular,
                5u64,
            ),
            (
                FactorError::NonFinite { first_offset: 37 },
                FailCode::NonFinite,
                37,
            ),
            (
                FactorError::Unsupported("not SPD".into()),
                FailCode::Unsupported,
                0,
            ),
            (FactorError::Internal("crew died".into()), FailCode::Internal, 0),
        ];
        for (err, code, detail) in cases {
            let f = Failure::from_error(&err);
            assert_eq!(f.code, code, "{err:?}");
            assert_eq!(f.detail, detail, "{err:?}");
            assert_eq!(f.reason, err.to_string());
        }
    }

    #[test]
    fn bad_magic_and_bad_version_are_corrupt() {
        let mut f = encode_goodbye();
        f[0] = b'X';
        assert!(matches!(read_all(&f), ReadEvent::Corrupt(_)));
        let mut f = encode_goodbye();
        f[2] = 9;
        match read_all(&f) {
            ReadEvent::Corrupt(e) => assert!(e.0.contains("version"), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn truncated_frames_are_corrupt_not_hangs() {
        let full = encode_hello(1, 1);
        // Truncated inside the header.
        assert!(matches!(read_all(&full[..7]), ReadEvent::Corrupt(_)));
        // Truncated inside the payload.
        assert!(matches!(read_all(&full[..HEADER_LEN + 1]), ReadEvent::Corrupt(_)));
        // Empty stream is a clean EOF.
        assert!(matches!(read_all(&[]), ReadEvent::Eof));
    }

    #[test]
    fn oversized_frame_is_drained_and_reported() {
        let big = encode_frame(T_FACTOR, 17, &vec![0u8; 1000]);
        let mut r = std::io::Cursor::new([big.clone(), encode_goodbye()].concat());
        match read_frame(&mut r, 100, &mut |_| true) {
            ReadEvent::Oversized(id, len) => {
                assert_eq!(id, 17);
                assert_eq!(len, 1000);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The stream stays framed: the next frame still parses.
        match read_frame(&mut r, 100, &mut |_| true) {
            ReadEvent::Frame(f) => assert_eq!(f.ty, T_GOODBYE),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn payload_length_must_match_dimensions() {
        let a = Matrix::random(4, 4, 1);
        let req = FactorReq {
            kind: FactorKind::Lu,
            priority: 0,
            deadline_ms: 0,
            bo: 0,
            bi: 0,
            a: WireMat::F64(a),
        };
        let frame = encode_factor_req(1, &req);
        // Chop one element off the data: decode must fail, not panic.
        let short = &frame[HEADER_LEN..frame.len() - 8];
        assert!(decode_factor_req(short).is_err());
        // Extend with trailing bytes: also rejected.
        let mut long = frame[HEADER_LEN..].to_vec();
        long.extend_from_slice(&[0; 4]);
        assert!(decode_factor_req(&long).is_err());
    }

    #[test]
    fn bad_enum_codes_are_rejected() {
        let a = Matrix::random(2, 2, 1);
        let req = FactorReq {
            kind: FactorKind::Lu,
            priority: 0,
            deadline_ms: 0,
            bo: 0,
            bi: 0,
            a: WireMat::F64(a),
        };
        let frame = encode_factor_req(1, &req);
        let mut p = frame[HEADER_LEN..].to_vec();
        p[0] = 7; // kind
        assert!(decode_factor_req(&p).is_err());
        let mut p = frame[HEADER_LEN..].to_vec();
        p[1] = 9; // precision
        assert!(decode_factor_req(&p).is_err());
        assert!(RejectCode::parse(0).is_none());
        assert!(RejectCode::parse(6).is_none());
    }
}
