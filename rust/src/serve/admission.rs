//! Admission control for the serve daemon: bounded pending work, typed
//! load-shedding, per-client fairness, and the drain state machine
//! (DESIGN.md §14.5–§14.6).
//!
//! The daemon's compute layer ([`crate::serve::LuServer`]) multiplexes a
//! fixed worker pool; accepting unbounded work would only grow the queue
//! and every request's latency. This module is the front door that says
//! *no* early and cheaply, before any matrix payload is admitted to the
//! queue:
//!
//! - **Global bound** (`max_pending`): at most this many requests may be
//!   admitted-but-not-yet-responded across all connections; beyond it,
//!   requests are rejected [`RejectCode::Overloaded`].
//! - **Fairness quota** (`max_client_inflight`): one connection may hold
//!   at most this many of the pending slots, so a greedy pipelining
//!   client cannot starve the rest. The invariant (tested in
//!   `admission::tests` and end-to-end in `tests/serve_net.rs`): *for any
//!   client c at any time, `inflight(c) ≤ max_client_inflight`, and a
//!   client below its quota is refused only if the global bound is
//!   reached or the daemon is draining.*
//! - **Size bound** (`max_dim`): any matrix dimension above it is
//!   rejected [`RejectCode::TooLarge`] before decode buffers are grown.
//! - **Drain** ([`AdmissionCtl::start_drain`]): flips the state machine
//!   from `Accepting` to `Draining`; every later admission attempt gets
//!   [`RejectCode::Draining`] while already-admitted work runs (or is
//!   ET-cancelled at the grace deadline) and its responses flush. When
//!   the last pending request is released the state is observably
//!   `Drained` ([`AdmissionCtl::is_drained`]).
//!
//! All counters are lock-free (`AtomicUsize`/`AtomicU64` CAS); admission
//! sits on the reader-thread hot path of every request.

use super::proto::RejectCode;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Admission bounds (the operator-tunable knobs of `mlu serve`).
#[derive(Copy, Clone, Debug)]
pub struct AdmissionCfg {
    /// Global cap on admitted-but-unanswered requests (all connections).
    pub max_pending: usize,
    /// Per-connection cap on admitted-but-unanswered requests.
    pub max_client_inflight: usize,
    /// Largest accepted matrix dimension (rows or cols).
    pub max_dim: usize,
}

impl Default for AdmissionCfg {
    fn default() -> Self {
        Self {
            max_pending: 64,
            max_client_inflight: 16,
            max_dim: 8192,
        }
    }
}

/// Monotone counters the daemon exports ([`AdmissionCtl::stats`]).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests admitted to the compute queue.
    pub admitted: u64,
    /// Rejections: global pending bound or fairness quota hit.
    pub rejected_overloaded: u64,
    /// Rejections: matrix dimension above `max_dim`.
    pub rejected_too_large: u64,
    /// Rejections: arrived while draining.
    pub rejected_draining: u64,
}

/// The admission-control state machine (module docs above). One per
/// daemon; shared by every connection's reader thread.
pub struct AdmissionCtl {
    cfg: AdmissionCfg,
    pending: AtomicUsize,
    per_client: Mutex<HashMap<u64, usize>>,
    draining: AtomicBool,
    admitted: AtomicU64,
    rej_overloaded: AtomicU64,
    rej_too_large: AtomicU64,
    rej_draining: AtomicU64,
}

impl AdmissionCtl {
    /// New controller in the `Accepting` state.
    pub fn new(cfg: AdmissionCfg) -> Self {
        Self {
            cfg,
            pending: AtomicUsize::new(0),
            per_client: Mutex::new(HashMap::new()),
            draining: AtomicBool::new(false),
            admitted: AtomicU64::new(0),
            rej_overloaded: AtomicU64::new(0),
            rej_too_large: AtomicU64::new(0),
            rej_draining: AtomicU64::new(0),
        }
    }

    /// The bounds this controller enforces.
    pub fn cfg(&self) -> &AdmissionCfg {
        &self.cfg
    }

    /// Try to admit one request from `client` with matrix dimensions
    /// `dims`. On `Ok`, the caller holds one pending slot and **must**
    /// eventually call [`release`](Self::release) exactly once (after
    /// the response or rejection has been written, or the client
    /// reaped). On `Err`, nothing is held.
    ///
    /// Check order: drain state, then size, then quotas — a daemon that
    /// is draining says so even for oversized requests, and an oversized
    /// request is refused without charging the client's quota.
    pub fn try_admit(&self, client: u64, dims: (usize, usize)) -> Result<(), RejectCode> {
        if self.draining.load(Ordering::Acquire) {
            self.rej_draining.fetch_add(1, Ordering::Relaxed);
            return Err(RejectCode::Draining);
        }
        if dims.0 > self.cfg.max_dim || dims.1 > self.cfg.max_dim {
            self.rej_too_large.fetch_add(1, Ordering::Relaxed);
            return Err(RejectCode::TooLarge);
        }
        // Take the per-client slot first (under the map lock), then the
        // global slot via CAS; back out the client slot if the global
        // bound loses the race.
        {
            let mut map = self.per_client.lock().unwrap();
            let slot = map.entry(client).or_insert(0);
            if *slot >= self.cfg.max_client_inflight {
                self.rej_overloaded.fetch_add(1, Ordering::Relaxed);
                return Err(RejectCode::Overloaded);
            }
            *slot += 1;
        }
        let mut cur = self.pending.load(Ordering::Relaxed);
        loop {
            if cur >= self.cfg.max_pending {
                // Back the per-client slot out, dropping the entry at
                // zero exactly as `release` does — otherwise every new
                // client refused at the global bound would leave a
                // permanent zero-count entry behind (unbounded map
                // growth under sustained overload).
                let mut map = self.per_client.lock().unwrap();
                if let Some(slot) = map.get_mut(&client) {
                    *slot -= 1;
                    if *slot == 0 {
                        map.remove(&client);
                    }
                }
                self.rej_overloaded.fetch_add(1, Ordering::Relaxed);
                return Err(RejectCode::Overloaded);
            }
            match self.pending.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Return `client`'s pending slot after its response (or rejection
    /// for an admitted-then-failed request) has been flushed, or after
    /// the connection was reaped. Pairs one-to-one with a successful
    /// [`try_admit`](Self::try_admit).
    pub fn release(&self, client: u64) {
        {
            let mut map = self.per_client.lock().unwrap();
            match map.get_mut(&client) {
                Some(slot) if *slot > 0 => {
                    *slot -= 1;
                    if *slot == 0 {
                        map.remove(&client);
                    }
                }
                _ => debug_assert!(false, "release without matching admit (client {client})"),
            }
        }
        let prev = self.pending.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "global release without matching admit");
    }

    /// Admitted-but-unanswered requests right now (all connections).
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    /// Clients currently holding at least one pending slot. Entries are
    /// removed when their count returns to zero (both on release and on
    /// a global-bound back-out), so this stays bounded by the live
    /// connection count — not by every client id ever seen.
    pub fn tracked_clients(&self) -> usize {
        self.per_client.lock().unwrap().len()
    }

    /// `client`'s admitted-but-unanswered requests right now.
    pub fn client_inflight(&self, client: u64) -> usize {
        self.per_client
            .lock()
            .unwrap()
            .get(&client)
            .copied()
            .unwrap_or(0)
    }

    /// Enter the `Draining` state: every subsequent
    /// [`try_admit`](Self::try_admit) is refused with
    /// [`RejectCode::Draining`]. Idempotent; there is no way back to
    /// `Accepting` (a drain is the start of a shutdown).
    pub fn start_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    /// Whether the controller refuses new work.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Terminal state: draining *and* every admitted request released.
    pub fn is_drained(&self) -> bool {
        self.is_draining() && self.pending() == 0
    }

    /// Snapshot of the monotone admission counters.
    pub fn stats(&self) -> AdmissionStats {
        AdmissionStats {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected_overloaded: self.rej_overloaded.load(Ordering::Relaxed),
            rejected_too_large: self.rej_too_large.load(Ordering::Relaxed),
            rejected_draining: self.rej_draining.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(max_pending: usize, max_client: usize, max_dim: usize) -> AdmissionCtl {
        AdmissionCtl::new(AdmissionCfg {
            max_pending,
            max_client_inflight: max_client,
            max_dim,
        })
    }

    #[test]
    fn global_bound_sheds_overload() {
        let c = ctl(2, 10, 100);
        assert!(c.try_admit(1, (10, 10)).is_ok());
        assert!(c.try_admit(2, (10, 10)).is_ok());
        assert_eq!(c.try_admit(3, (10, 10)), Err(RejectCode::Overloaded));
        c.release(1);
        assert!(c.try_admit(3, (10, 10)).is_ok());
        assert_eq!(c.pending(), 2);
        let s = c.stats();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.rejected_overloaded, 1);
    }

    #[test]
    fn fairness_quota_caps_one_client_but_not_the_next() {
        // The fairness invariant: the greedy client is refused at its
        // quota while another client is still admitted.
        let c = ctl(10, 2, 100);
        assert!(c.try_admit(7, (10, 10)).is_ok());
        assert!(c.try_admit(7, (10, 10)).is_ok());
        assert_eq!(c.try_admit(7, (10, 10)), Err(RejectCode::Overloaded));
        assert_eq!(c.client_inflight(7), 2);
        assert!(c.try_admit(8, (10, 10)).is_ok(), "other client starved");
        c.release(7);
        assert!(c.try_admit(7, (10, 10)).is_ok());
    }

    #[test]
    fn too_large_is_rejected_without_charging_quota() {
        let c = ctl(10, 1, 64);
        assert_eq!(c.try_admit(1, (65, 10)), Err(RejectCode::TooLarge));
        assert_eq!(c.try_admit(1, (10, 65)), Err(RejectCode::TooLarge));
        assert_eq!(c.client_inflight(1), 0);
        // The quota is untouched: an in-bounds request still fits.
        assert!(c.try_admit(1, (64, 64)).is_ok());
        assert_eq!(c.stats().rejected_too_large, 2);
    }

    #[test]
    fn global_bound_backout_leaves_no_client_entry_behind() {
        // A full global queue refuses every newcomer; each refusal must
        // back its per-client slot out *and* drop the zero-count map
        // entry, or sustained overload from short-lived connections
        // grows the map without bound.
        let c = ctl(1, 4, 100);
        assert!(c.try_admit(1, (10, 10)).is_ok());
        assert_eq!(c.tracked_clients(), 1);
        for client in 2..100u64 {
            assert_eq!(c.try_admit(client, (10, 10)), Err(RejectCode::Overloaded));
        }
        assert_eq!(c.tracked_clients(), 1, "rejected clients leaked map entries");
        c.release(1);
        assert_eq!(c.tracked_clients(), 0);
    }

    #[test]
    fn drain_state_machine_reaches_drained() {
        let c = ctl(10, 10, 100);
        assert!(c.try_admit(1, (10, 10)).is_ok());
        assert!(!c.is_draining());
        c.start_drain();
        assert!(c.is_draining());
        assert!(!c.is_drained(), "still one pending");
        assert_eq!(c.try_admit(2, (10, 10)), Err(RejectCode::Draining));
        // Draining outranks every other rejection reason.
        assert_eq!(c.try_admit(2, (1000, 1000)), Err(RejectCode::Draining));
        c.release(1);
        assert!(c.is_drained());
        assert_eq!(c.stats().rejected_draining, 2);
    }

    #[test]
    fn release_frees_both_global_and_client_slots() {
        let c = ctl(2, 2, 100);
        assert!(c.try_admit(5, (1, 1)).is_ok());
        assert!(c.try_admit(5, (1, 1)).is_ok());
        c.release(5);
        c.release(5);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.client_inflight(5), 0);
        // Both bounds fully recovered.
        assert!(c.try_admit(5, (1, 1)).is_ok());
        assert!(c.try_admit(5, (1, 1)).is_ok());
    }

    #[test]
    fn concurrent_admits_never_exceed_the_bound() {
        use std::sync::Arc;
        let c = Arc::new(ctl(8, 8, 100));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut admitted = 0u64;
                for _ in 0..500 {
                    if c.try_admit(t, (10, 10)).is_ok() {
                        assert!(c.pending() <= 8, "pending bound violated");
                        admitted += 1;
                        c.release(t);
                    }
                }
                admitted
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.stats().admitted, total);
    }
}
