//! §serve — the **batched multi-problem factorization scheduler**
//! (DESIGN.md §10).
//!
//! The paper's Worker-Sharing and Early-Termination mechanisms move
//! threads between the two branches of *one* look-ahead factorization.
//! This layer generalizes both across *problems*: an [`LuServer`] accepts
//! a queue of factorization requests (mixed sizes, priorities, optional
//! deadlines — and since the factorization-family refactor, mixed
//! [`FactorKind`]s) and multiplexes them over a single [`Pool`].
//!
//! Since the precision redesign (DESIGN.md §12) the queue is
//! **precision-heterogeneous**: `f32` and `f64` requests — created with
//! [`LuRequest::new`] over a [`Mat<S>`] of either sealed scalar type —
//! and mixed-precision *solve* requests ([`SolveRequest`], the
//! `lu_solve_mixed` workload) share one priority queue, one crew
//! registry, one packing arena, and one cost model. Typed results come
//! back through typed handles (`submit::<f32>` returns a
//! `JobHandle<JobResult<f32>>`); internally each queue entry is a
//! type-erased lead closure, so the scheduler itself never branches on
//! precision. The cost model prices an `f32` problem at half the modeled
//! seconds of its `f64` twin ([`crate::scalar::Scalar::FLOP_RATE`]), and
//! trace spans are tagged `req{id}:{kind}:{prec}` so Gantt lanes name
//! both.
//!
//! Scheduling model — every pool worker runs the same `serve_loop`:
//!
//! 1. **Lead.** Pop the highest-priority queued request and drive its
//!    factorization to completion ([`driver::drive`]), leading a
//!    malleable [`Crew`] registered in the [`CrewRegistry`].
//! 2. **Float.** If the queue is empty, enlist as a member of the most
//!    starved in-flight crew (priority- and remaining-FLOPs-aware, using
//!    [`crate::sim::costmodel`] estimates) under a revocable lease
//!    ([`crate::pool::CrewShared::member_loop_while`]). The lease is
//!    revoked — at a job boundary, so no chunk is lost or re-run — when
//!    the registry's picture changes or new work is queued.
//!
//! Thus any finished or blocked problem's workers flow to whichever
//! problem is furthest behind: the WS rule lifted from two branches to N
//! problems. Early Termination generalizes too: [`JobHandle::cancel`]
//! (or an expired deadline) stops a request at its next panel
//! checkpoint, leaving a clean factored prefix and returning its crew to
//! the pool.
//!
//! Since the hybrid-scheduling PR (DESIGN.md §13) a floater that joins a
//! crew mid-update is also rebalanced *within* the update: the trailing
//! macro-loops run under the static/dynamic tile-stealing schedule, so a
//! donated worker drains the crew's dynamic tail and steals from its
//! static slices instead of idling until the next iteration. The
//! leader's panel checkpoints feed the observed stolen-tile fraction
//! back into the lease ([`Lease::steal_pressure`]), and the starvation
//! score weights crews that convert donated workers into steals above
//! crews whose updates are already balanced — stolen-tile counts feeding
//! lease sizing.
//!
//! **Fault model** (DESIGN.md §15): a request that *ran* but failed —
//! exactly singular matrix, non-finite payload, panicked worker — is
//! completed with a typed [`crate::factor::FactorError`] in its
//! result's `error` field, never by hanging its waiter. A panicking
//! leader is caught in the serve loop, its registry entry withdrawn,
//! and its handle fulfilled with `FactorError::Internal`; a panicking
//! crew member poisons its crew, which the drivers surface the same
//! way. The serve layer forbids `unwrap`/`expect` outside tests so a
//! poisoned mutex can never take down an unrelated request.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod admission;
pub mod client;
pub mod driver;
pub mod net;
pub mod proto;
pub mod registry;

pub use driver::{choose_strategy, Strategy};
pub use registry::{CrewRegistry, Lease};

use crate::blis::{BlisParams, PackArena, SmallBundle};
use crate::factor::{DriverFamily, FactorError, FactorKind};
use crate::matrix::{Mat, Matrix};
use crate::pool::{Crew, EntryPolicy, Pool, TaskHandle};
use crate::replay::capture::{self, DecisionKind};
use crate::replay::{bundle, factor_digest, solve_digest};
use crate::scalar::Scalar;
use crate::sim::HwModel;
use crate::solve::{SolveCtl, SolvePrec};
use crossbeam_utils::Backoff;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Copy, Clone, Debug)]
pub struct ServeConfig {
    /// Pool workers serving the queue (each alternates between leading a
    /// request and floating into starved crews).
    pub workers: usize,
    /// Default outer block size for requests that don't override it.
    pub bo: usize,
    /// Default inner (panel) block size.
    pub bi: usize,
    /// BLIS blocking parameters shared by every request's kernels.
    pub params: BlisParams,
    /// How floating workers enter an in-flight kernel.
    pub entry: EntryPolicy,
    /// Cost model used for remaining-work estimates.
    pub hw: HwModel,
    /// Route small square LU requests through the interleaved
    /// small-batch fast path (DESIGN.md §18): same-shape same-precision
    /// requests no larger than [`HwModel::small_threshold`] are grouped
    /// into SIMD-width bundles and factored lane-parallel by
    /// [`crate::blis::smallbatch`] instead of leading a crew. Off by
    /// default; the threshold moves placement only, never results
    /// (`tests/smallbatch_agree.rs`).
    pub interleave: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            bo: 64,
            bi: 16,
            params: BlisParams::default(),
            entry: EntryPolicy::JobBoundary,
            hw: HwModel::default(),
            interleave: false,
        }
    }
}

/// One factorization request of any [`FactorKind`], in precision `S`
/// (`f64` unless the matrix says otherwise — the name predates the
/// factorization-family refactor).
pub struct LuRequest<S: Scalar = f64> {
    /// The matrix to factorize (consumed; returned in the result).
    pub a: Mat<S>,
    /// Which factorization to run (`Lu` by default).
    pub kind: FactorKind,
    /// Higher runs first and attracts floaters more strongly.
    pub priority: u8,
    /// Budget after which the request is ET-cancelled.
    pub deadline: Option<Duration>,
    /// Outer block-size override (server default when `None`).
    pub bo: Option<usize>,
    /// Inner block-size override.
    pub bi: Option<usize>,
    /// Originating network connection id, when the request arrived via
    /// the [`net`] daemon (`None` for in-process submissions). Folded
    /// into the trace tag (`req{id}@c{client}:{kind}:{prec}`) so
    /// per-request Gantt lanes name the connection, and used by
    /// admission accounting.
    pub client: Option<u64>,
    /// Which driver family factorizes the request: the WS+ET look-ahead
    /// driver (default) or the tile-DAG dataflow runtime
    /// ([`crate::tilert`], DESIGN.md §17). Floaters donated to a
    /// DAG-family request attach as extra DAG executors instead of crew
    /// members.
    pub driver: DriverFamily,
}

impl<S: Scalar> LuRequest<S> {
    /// A default-priority LU request with server-default block sizes.
    pub fn new(a: Mat<S>) -> Self {
        Self {
            a,
            kind: FactorKind::Lu,
            priority: 0,
            deadline: None,
            bo: None,
            bi: None,
            client: None,
            driver: DriverFamily::default(),
        }
    }

    /// Select the factorization kind (Cholesky requests must carry a
    /// square SPD matrix; a rectangular one is rejected at lead time and
    /// comes back `cancelled`).
    pub fn with_kind(mut self, kind: FactorKind) -> Self {
        self.kind = kind;
        self
    }

    /// Set the scheduling priority (higher runs first).
    pub fn with_priority(mut self, p: u8) -> Self {
        self.priority = p;
        self
    }

    /// Set the wall-clock budget after which the request is cancelled.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Override the server's default outer/inner block sizes.
    pub fn with_blocks(mut self, bo: usize, bi: usize) -> Self {
        self.bo = Some(bo);
        self.bi = Some(bi);
        self
    }

    /// Tag the request with its originating network connection id (set
    /// by the [`net`] daemon; in-process callers normally leave it
    /// unset).
    pub fn with_client(mut self, client: u64) -> Self {
        self.client = Some(client);
        self
    }

    /// Select the driver family that factorizes this request
    /// ([`DriverFamily::Lookahead`] by default).
    pub fn with_driver(mut self, driver: DriverFamily) -> Self {
        self.driver = driver;
        self
    }
}

/// A mixed-precision (or precision-selected) linear-system solve
/// request: the `lu_solve_mixed` workload as a queue citizen. The system
/// is given in `f64`; `prec` selects the factorization arithmetic
/// ([`SolvePrec::Mixed`] = `f32` factors + `f64` iterative refinement to
/// double-precision backward error — DESIGN.md §12).
pub struct SolveRequest {
    /// The (square) system matrix.
    pub a: Matrix,
    /// The right-hand side (`b.len() == a.rows()`).
    pub b: Vec<f64>,
    /// Which arithmetic the solve runs in.
    pub prec: SolvePrec,
    /// Higher runs first and attracts floaters more strongly.
    pub priority: u8,
    /// Budget after which the request is ET-cancelled.
    pub deadline: Option<Duration>,
    /// Outer block-size override (server default when `None`).
    pub bo: Option<usize>,
    /// Inner block-size override.
    pub bi: Option<usize>,
    /// Originating network connection id (see [`LuRequest::client`]).
    pub client: Option<u64>,
}

impl SolveRequest {
    /// A default-priority mixed-precision solve request.
    pub fn new(a: Matrix, b: Vec<f64>) -> Self {
        Self {
            a,
            b,
            prec: SolvePrec::Mixed,
            priority: 0,
            deadline: None,
            bo: None,
            bi: None,
            client: None,
        }
    }

    /// Select the solve arithmetic (default [`SolvePrec::Mixed`]).
    pub fn with_prec(mut self, prec: SolvePrec) -> Self {
        self.prec = prec;
        self
    }

    /// Set the scheduling priority (higher runs first).
    pub fn with_priority(mut self, p: u8) -> Self {
        self.priority = p;
        self
    }

    /// Set the wall-clock budget after which the request is cancelled.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Tag the request with its originating network connection id (see
    /// [`LuRequest::with_client`]).
    pub fn with_client(mut self, client: u64) -> Self {
        self.client = Some(client);
        self
    }
}

/// Completed (or cancelled) factorization request, in precision `S`.
#[derive(Debug)]
pub struct JobResult<S: Scalar = f64> {
    /// Request id assigned at submission.
    pub id: u64,
    /// The factorization that ran.
    pub kind: FactorKind,
    /// The matrix, now holding the factors (a clean factored prefix of
    /// `cols_done` columns if the request was cancelled).
    pub a: Mat<S>,
    /// Absolute pivots for the committed columns (LU only).
    pub ipiv: Vec<usize>,
    /// Householder scalar factors for the committed columns (QR only).
    pub tau: Vec<S>,
    /// Columns fully factorized and committed.
    pub cols_done: usize,
    /// Whether the request was cancelled (by handle, deadline, or a
    /// malformed problem, e.g. a rectangular Cholesky).
    pub cancelled: bool,
    /// Wall seconds from submission to completion.
    pub secs: f64,
    /// Typed numerical/fault status (DESIGN.md §15): `None` for a clean
    /// run; `ExactlySingular`/`NonFinite`/`Unsupported` for numerical
    /// failures of the *input*; `Internal` when the daemon faulted
    /// (panicked leader, poisoned crew) while executing it. The [`net`]
    /// layer maps this to a `FAILED` wire frame.
    pub error: Option<FactorError>,
}

/// Completed (or cancelled) solve request.
#[derive(Debug)]
pub struct SolveJobResult {
    /// Request id assigned at submission.
    pub id: u64,
    /// The solve arithmetic that ran.
    pub prec: SolvePrec,
    /// The solution in `f64` (empty if cancelled before completion).
    pub x: Vec<f64>,
    /// Refinement sweeps performed (mixed path only).
    pub refine_iters: usize,
    /// Final normwise backward error (`f64`; infinite if cancelled).
    pub backward_error: f64,
    /// Whether the precision path's convergence criterion was met.
    pub converged: bool,
    /// Whether the request was cancelled (handle or deadline).
    pub cancelled: bool,
    /// Wall seconds from submission to completion.
    pub secs: f64,
    /// Typed numerical/fault status of the factor stage (see
    /// [`JobResult::error`]); e.g. `ExactlySingular` when the working
    /// precision's pivot is exactly zero, which also explains a
    /// `converged == false` with infinite backward error.
    pub error: Option<FactorError>,
}

struct JobState<R> {
    done: Mutex<Option<R>>,
    cv: Condvar,
    cancel: AtomicBool,
}

impl<R> JobState<R> {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            done: Mutex::new(None),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
        })
    }
}

/// Handle returned by [`LuServer::submit`] / [`LuServer::submit_solve`],
/// typed by the result it will deliver (`JobResult<S>` or
/// [`SolveJobResult`]).
pub struct JobHandle<R = JobResult> {
    id: u64,
    state: Arc<JobState<R>>,
}

impl<R> JobHandle<R> {
    /// The request id (matches the result's `id` and trace tags).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request-level early termination: drop the job if still queued, or
    /// stop it at its next panel checkpoint. The crew it occupied
    /// returns to the pool either way.
    pub fn cancel(&self) {
        self.state.cancel.store(true, Ordering::Release);
    }

    /// Whether the result is ready (non-blocking).
    pub fn is_done(&self) -> bool {
        self.state
            .done
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_some()
    }

    /// Block until the request completes (or is cancelled) and take the
    /// result.
    pub fn wait(self) -> R {
        let mut slot = self.state.done.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self
                .state
                .cv
                .wait(slot)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// A type-erased cancel handle that outlives `wait(self)`: the
    /// [`net`] daemon keeps one per outstanding request so a drain
    /// deadline (or a vanished client) can still ET the job after the
    /// writer thread has consumed the typed handle.
    pub fn cancel_token(&self) -> CancelToken
    where
        R: Send + 'static,
    {
        let state = Arc::clone(&self.state);
        CancelToken(Arc::new(move || {
            state.cancel.store(true, Ordering::Release);
        }))
    }
}

/// Type-erased request-cancellation handle (see
/// [`JobHandle::cancel_token`]). Cloneable; calling [`CancelToken::cancel`]
/// is idempotent and stops the request at its next panel checkpoint.
#[derive(Clone)]
pub struct CancelToken(Arc<dyn Fn() + Send + Sync>);

impl CancelToken {
    /// Request early termination (same semantics as [`JobHandle::cancel`]).
    pub fn cancel(&self) {
        (self.0)();
    }
}

/// One queued request: the scheduling key plus a type-erased lead
/// closure (the precision and kind live inside the closure, so the
/// queue itself is precision-heterogeneous).
struct QueuedJob {
    id: u64,
    seq: u64,
    priority: u8,
    /// Drives the request to completion and fulfills its typed handle.
    run: Box<dyn FnOnce(&ServerState) + Send>,
    /// Fulfills the handle with a typed-failure result (panic recovery:
    /// the serve loop passes the `FactorError::Internal` describing the
    /// leader's panic).
    abort: Box<dyn FnOnce(FactorError) + Send>,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}

impl Eq for QueuedJob {}

impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedJob {
    /// Max-heap key: priority first, then FIFO within a priority class.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

/// How long a ragged (not yet full) bundle may wait for lanemates while
/// per-problem work keeps the queue busy. Bounds small-request latency
/// under mixed load; when the heap is empty a ragged bundle flushes
/// immediately instead.
const BUNDLE_LINGER: Duration = Duration::from_millis(2);

/// Staging-bucket key for the interleaved strategy: bundles mix only
/// same-shape same-precision problems (mixed-size queues are *never*
/// bundled together — pinned in `tests/smallbatch_agree.rs`).
#[derive(Copy, Clone, PartialEq, Eq, Hash)]
struct SmallKey {
    n: u32,
    prec: u8,
}

/// Bundle width for a staging bucket's precision code.
fn small_lanes(prec: u8) -> usize {
    if prec == bundle::prec_code::<f32>() {
        f32::SIMD_LANES
    } else {
        f64::SIMD_LANES
    }
}

/// Typed payload of one staged small request (precision `S` matches the
/// bucket's prec code).
struct SmallReq<S: Scalar> {
    id: u64,
    a: Mat<S>,
    submitted: Instant,
    jstate: Arc<JobState<JobResult<S>>>,
}

/// One staged small request: the scheduling key lives in its bucket;
/// the precision lives inside the type-erased payload (downcast by the
/// bundle leader, which knows the bucket's prec code).
struct StagedSmall {
    id: u64,
    submitted: Instant,
    /// A `Box<SmallReq<S>>` for the bucket's precision.
    payload: Box<dyn std::any::Any + Send>,
    /// Fulfills the handle with a typed failure (panic recovery, like
    /// [`QueuedJob::abort`]).
    abort: Box<dyn FnOnce(FactorError) + Send>,
}

struct ServerState {
    queue: Mutex<BinaryHeap<QueuedJob>>,
    /// Mirror of `queue.len()` readable without the lock (floaters poll
    /// it from inside crew job waits).
    queued: AtomicUsize,
    registry: CrewRegistry,
    stop: AtomicBool,
    cfg: ServeConfig,
    /// Packing arena shared by every request's crew — across kinds *and*
    /// precisions (the arena's granule is `f64`; `f32` packings view the
    /// same size-classed buffers): once the largest request shape has
    /// been served, later factorizations lease their packed buffers
    /// without allocating (DESIGN.md §9).
    arena: Arc<PackArena>,
    /// Staging buckets of the interleaved strategy: small requests wait
    /// here (keyed by shape + precision) until a SIMD-width bundle fills
    /// or the queue idles (DESIGN.md §18).
    small: Mutex<HashMap<SmallKey, VecDeque<StagedSmall>>>,
    /// Mirror of the total staged count readable without the lock
    /// (serve loops and floaters poll it like `queued`).
    staged: AtomicUsize,
}

impl ServerState {
    fn pop(&self) -> Option<QueuedJob> {
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        let job = q.pop();
        self.queued.store(q.len(), Ordering::Release);
        job
    }

    fn push(&self, job: QueuedJob) {
        // Stop-check and push under one lock: shutdown() also sets
        // `stop` under this lock, so a job can never slip into the
        // queue after the serve loops were told to drain and exit
        // (its waiter would hang forever).
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        assert!(
            !self.stop.load(Ordering::Acquire),
            "LuServer::submit after shutdown"
        );
        q.push(job);
        self.queued.store(q.len(), Ordering::Release);
    }

    /// Stage a small request into its bundle bucket. Holds the queue
    /// lock for the stop-check, pairing with `shutdown()` exactly like
    /// `push` (lock order: queue, then small — everywhere).
    fn stage(&self, key: SmallKey, job: StagedSmall) {
        let _q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        assert!(
            !self.stop.load(Ordering::Acquire),
            "LuServer::submit after shutdown"
        );
        let mut sm = self.small.lock().unwrap_or_else(|e| e.into_inner());
        sm.entry(key).or_default().push_back(job);
        self.staged.fetch_add(1, Ordering::AcqRel);
    }

    /// Take the next bundle to execute: a full SIMD-width bundle from
    /// any bucket, else — when the per-problem heap is idle or a bucket
    /// head has lingered past [`BUNDLE_LINGER`] — the oldest ragged
    /// bucket. Returns the bucket key plus up to `small_lanes(prec)`
    /// members in FIFO order.
    fn pop_bundle(&self) -> Option<(SmallKey, Vec<StagedSmall>)> {
        if self.staged.load(Ordering::Acquire) == 0 {
            return None;
        }
        let mut sm = self.small.lock().unwrap_or_else(|e| e.into_inner());
        let mut pick: Option<SmallKey> = None;
        for (k, q) in sm.iter() {
            if q.len() >= small_lanes(k.prec) {
                pick = Some(*k);
                break;
            }
        }
        if pick.is_none() {
            let idle = self.queued.load(Ordering::Acquire) == 0;
            let mut oldest: Option<(SmallKey, Instant)> = None;
            for (k, q) in sm.iter() {
                if let Some(front) = q.front() {
                    let due = idle || front.submitted.elapsed() >= BUNDLE_LINGER;
                    let older = match oldest {
                        None => true,
                        Some((_, t)) => front.submitted < t,
                    };
                    if due && older {
                        oldest = Some((*k, front.submitted));
                    }
                }
            }
            pick = oldest.map(|(k, _)| k);
        }
        let key = pick?;
        let q = sm.get_mut(&key)?;
        let take = small_lanes(key.prec).min(q.len());
        let members: Vec<StagedSmall> = q.drain(..take).collect();
        if q.is_empty() {
            sm.remove(&key);
        }
        self.staged.fetch_sub(members.len(), Ordering::AcqRel);
        Some((key, members))
    }
}

/// The batched multi-problem factorization server (module docs above).
pub struct LuServer {
    pool: Pool,
    state: Arc<ServerState>,
    loops: Mutex<Vec<TaskHandle>>,
    next_id: AtomicU64,
}

impl LuServer {
    /// Spawn `cfg.workers` pool workers, each running a serve loop.
    pub fn new(cfg: ServeConfig) -> Self {
        let pool = Pool::new(cfg.workers.max(1));
        let state = Arc::new(ServerState {
            queue: Mutex::new(BinaryHeap::new()),
            queued: AtomicUsize::new(0),
            registry: CrewRegistry::new(),
            stop: AtomicBool::new(false),
            cfg,
            arena: Arc::new(PackArena::new()),
            small: Mutex::new(HashMap::new()),
            staged: AtomicUsize::new(0),
        });
        let loops = pool.broadcast(|_w| {
            let st = Arc::clone(&state);
            move || serve_loop(&st)
        });
        Self {
            pool,
            state,
            loops: Mutex::new(loops),
            next_id: AtomicU64::new(0),
        }
    }

    /// Number of pool workers serving requests.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// In-flight problem registry (exposed for tests and introspection).
    pub fn registry(&self) -> &CrewRegistry {
        &self.state.registry
    }

    /// Statistics of the packing arena shared by all requests' crews
    /// (steady-state serving must stop allocating — DESIGN.md §9).
    pub fn arena_stats(&self) -> crate::blis::ArenaStats {
        self.state.arena.stats()
    }

    /// Total small requests currently staged in interleave buckets
    /// (0 unless [`ServeConfig::interleave`] is on; exposed for tests
    /// and introspection).
    pub fn staged_small(&self) -> usize {
        self.state.staged.load(Ordering::Acquire)
    }

    /// Enqueue a factorization request in either precision; returns
    /// immediately with a typed handle. Admission (id, capture record,
    /// typed handle) happens first; the execution strategy
    /// ([`Strategy`]) is chosen after and decides placement only — the
    /// interleaved path stages the request into a bundle bucket, the
    /// per-problem path pushes it on the priority heap.
    pub fn submit<S: Scalar>(&self, req: LuRequest<S>) -> JobHandle<JobResult<S>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if capture::active() {
            capture_submit_factor(id, &req);
        }
        let jstate = JobState::<JobResult<S>>::new();
        let now = Instant::now();
        if choose_strategy(&self.state.cfg, &req) == Strategy::Interleaved {
            let key = SmallKey {
                n: req.a.cols() as u32,
                prec: bundle::prec_code::<S>(),
            };
            let kind = req.kind;
            let abort_state = Arc::clone(&jstate);
            let job = StagedSmall {
                id,
                submitted: now,
                payload: Box::new(SmallReq {
                    id,
                    a: req.a,
                    submitted: now,
                    jstate: Arc::clone(&jstate),
                }),
                abort: Box::new(move |err: FactorError| {
                    complete(
                        &abort_state,
                        JobResult::<S> {
                            id,
                            kind,
                            a: Mat::zeros(0, 0),
                            ipiv: Vec::new(),
                            tau: Vec::new(),
                            cols_done: 0,
                            cancelled: false,
                            secs: 0.0,
                            error: Some(err),
                        },
                    );
                }),
            };
            self.state.stage(key, job);
            return JobHandle { id, state: jstate };
        }
        let priority = req.priority;
        let run_state = Arc::clone(&jstate);
        let abort_state = Arc::clone(&jstate);
        let kind = req.kind;
        let job = QueuedJob {
            id,
            seq: id,
            priority,
            run: Box::new(move |state: &ServerState| {
                lead_factor::<S>(state, id, req, now, run_state);
            }),
            abort: Box::new(move |err: FactorError| {
                complete(
                    &abort_state,
                    JobResult::<S> {
                        id,
                        kind,
                        a: Mat::zeros(0, 0),
                        ipiv: Vec::new(),
                        tau: Vec::new(),
                        cols_done: 0,
                        cancelled: false,
                        secs: 0.0,
                        error: Some(err),
                    },
                );
            }),
        };
        self.state.push(job);
        JobHandle { id, state: jstate }
    }

    /// Enqueue a precision-selected linear-system solve (the
    /// mixed-precision workload); returns immediately with a typed
    /// handle.
    pub fn submit_solve(&self, req: SolveRequest) -> JobHandle<SolveJobResult> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if capture::active() {
            capture_submit_solve(id, &req);
        }
        let jstate = JobState::<SolveJobResult>::new();
        let now = Instant::now();
        let priority = req.priority;
        let prec = req.prec;
        let run_state = Arc::clone(&jstate);
        let abort_state = Arc::clone(&jstate);
        let job = QueuedJob {
            id,
            seq: id,
            priority,
            run: Box::new(move |state: &ServerState| {
                lead_solve(state, id, req, now, run_state);
            }),
            abort: Box::new(move |err: FactorError| {
                complete(
                    &abort_state,
                    SolveJobResult {
                        id,
                        prec,
                        x: Vec::new(),
                        refine_iters: 0,
                        backward_error: f64::INFINITY,
                        converged: false,
                        cancelled: false,
                        secs: 0.0,
                        error: Some(err),
                    },
                );
            }),
        };
        self.state.push(job);
        JobHandle { id, state: jstate }
    }

    /// Submit a whole batch (one precision) and wait for every result
    /// (returned in submission order).
    pub fn factorize_batch<S: Scalar>(&self, reqs: Vec<LuRequest<S>>) -> Vec<JobResult<S>> {
        let handles: Vec<JobHandle<JobResult<S>>> =
            reqs.into_iter().map(|r| self.submit(r)).collect();
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Stop accepting work, drain already-queued requests, and join the
    /// serve loops. Called automatically on drop.
    pub fn shutdown(&self) {
        {
            // Under the queue lock — see the pairing note in
            // `ServerState::push`.
            let _q = self.state.queue.lock().unwrap_or_else(|e| e.into_inner());
            self.state.stop.store(true, Ordering::Release);
        }
        for h in self
            .loops
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
        {
            h.wait();
        }
    }
}

impl Drop for LuServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One-call batch entry point: factorize all matrices (one precision) on
/// a fresh server, returning results in input order.
pub fn factorize_batch<S: Scalar>(mats: Vec<Mat<S>>, cfg: &ServeConfig) -> Vec<JobResult<S>> {
    let server = LuServer::new(*cfg);
    let reqs: Vec<LuRequest<S>> = mats.into_iter().map(LuRequest::new).collect();
    let out = server.factorize_batch(reqs);
    server.shutdown();
    out
}

/// Capture one factor submission (DESIGN.md §16.2): the replayable
/// request record (bit-exact payload) plus the invariant `Submit`
/// decision. Called with the capture known active.
fn capture_submit_factor<S: Scalar>(id: u64, req: &LuRequest<S>) {
    let (m, n) = (req.a.rows() as u64, req.a.cols() as u64);
    let kind = bundle::kind_code(req.kind);
    let prec = bundle::prec_code::<S>();
    let (bo, bi) = (req.bo.unwrap_or(0) as u64, req.bi.unwrap_or(0) as u64);
    capture::record_request(bundle::ReqRecord {
        id,
        kind,
        prec,
        priority: req.priority,
        cancelled: false,
        failed: false,
        m: m as u32,
        n: n as u32,
        bo: bo as u16,
        bi: bi as u16,
        deadline_ms: deadline_ms(req.deadline),
        client: req.client.unwrap_or(bundle::NO_CLIENT),
        cols_done: 0,
        digest: 0,
        data: bundle::mat_to_le(&req.a),
        rhs: Vec::new(),
    });
    capture::record(
        DecisionKind::Submit,
        id,
        (m << 32) | n,
        u64::from(kind)
            | (u64::from(prec) << 8)
            | (u64::from(req.priority) << 16)
            // Driver-family code in bits 24–31 (0 = look-ahead, so
            // bundles captured before DESIGN.md §17 replay unchanged).
            | (u64::from(req.driver.code()) << 24)
            | (bo << 32)
            | (bi << 48),
    );
}

/// Capture one solve submission (see [`capture_submit_factor`]).
fn capture_submit_solve(id: u64, req: &SolveRequest) {
    let (m, n) = (req.a.rows() as u64, req.a.cols() as u64);
    let prec = bundle::solve_prec_code(req.prec);
    let (bo, bi) = (req.bo.unwrap_or(0) as u64, req.bi.unwrap_or(0) as u64);
    capture::record_request(bundle::ReqRecord {
        id,
        kind: bundle::REQ_SOLVE,
        prec,
        priority: req.priority,
        cancelled: false,
        failed: false,
        m: m as u32,
        n: n as u32,
        bo: bo as u16,
        bi: bi as u16,
        deadline_ms: deadline_ms(req.deadline),
        client: req.client.unwrap_or(bundle::NO_CLIENT),
        cols_done: 0,
        digest: 0,
        data: bundle::mat_to_le(&req.a),
        rhs: bundle::rhs_to_le(&req.b),
    });
    capture::record(
        DecisionKind::Submit,
        id,
        (m << 32) | n,
        u64::from(bundle::REQ_SOLVE)
            | (u64::from(prec) << 8)
            | (u64::from(req.priority) << 16)
            | (bo << 32)
            | (bi << 48),
    );
}

fn deadline_ms(d: Option<Duration>) -> u32 {
    d.map(|d| d.as_millis().min(u128::from(u32::MAX)) as u32)
        .unwrap_or(0)
}

/// One pool worker's scheduling loop: lead the highest-priority queued
/// request, else float into the most starved in-flight crew, else wait.
fn serve_loop(state: &ServerState) {
    let backoff = Backoff::new();
    loop {
        // Interleaved strategy first: a full SIMD-width bundle runs
        // ahead of per-problem work (it retires `width` requests in one
        // kernel pass); ragged bundles flush when the heap idles or
        // after a bounded linger (see `ServerState::pop_bundle`).
        if let Some((key, mut members)) = state.pop_bundle() {
            // Pull the abort closures out before the payloads move into
            // the leader, mirroring the per-problem panic recovery.
            let aborts: Vec<Box<dyn FnOnce(FactorError) + Send>> = members
                .iter_mut()
                .map(|m| {
                    std::mem::replace(&mut m.abort, Box::new(|_| {}))
                        as Box<dyn FnOnce(FactorError) + Send>
                })
                .collect();
            let ids: Vec<u64> = members.iter().map(|m| m.id).collect();
            let led = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                lead_small_bundle(key, members)
            }));
            if let Err(payload) = led {
                let msg = crate::pool::panic_message(payload.as_ref());
                eprintln!("serve: small bundle {ids:?} panicked ({msg}); reported as failed");
                for abort in aborts {
                    abort(FactorError::Internal(format!(
                        "bundle leader panicked: {msg}"
                    )));
                }
            }
            backoff.reset();
            continue;
        }
        if let Some(job) = state.pop() {
            let QueuedJob {
                id, run, abort, ..
            } = job;
            // A panicking request must not wedge its waiter or leak its
            // registry entry (that would strand floaters on a dead crew).
            let led = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(state)));
            if let Err(payload) = led {
                state.registry.unregister(id);
                let msg = crate::pool::panic_message(payload.as_ref());
                eprintln!("serve: request {id} panicked ({msg}); reported as failed");
                abort(FactorError::Internal(format!(
                    "request leader panicked: {msg}"
                )));
            }
            backoff.reset();
            continue;
        }
        if state.stop.load(Ordering::Acquire)
            && state.queued.load(Ordering::Acquire) == 0
            && state.staged.load(Ordering::Acquire) == 0
        {
            break;
        }
        let e0 = state.registry.epoch();
        if let Some(lease) = state.registry.most_starved() {
            // Environmental capture record: which crew this floater
            // donated itself to, at which registry epoch. Timing-shaped,
            // so never certified — but it is exactly the context a
            // divergence investigation (or a policy sweep) wants.
            capture::record(DecisionKind::WsJoin, lease.id, e0, 0);
            // Donate this worker until the picture changes: the crew
            // closes, a problem arrives or finishes, queued work appears,
            // or the server stops.
            let donate = || {
                state.registry.epoch() == e0
                    && state.queued.load(Ordering::Acquire) == 0
                    && state.staged.load(Ordering::Acquire) == 0
                    && !state.stop.load(Ordering::Acquire)
            };
            // DAG-family requests publish their scheduler in the lease's
            // DAG slot: attach as an extra deterministic executor there.
            // Crew-family requests keep the slot closed, so the floater
            // takes the member-loop path into the WS+ET kernels.
            if lease.dag.attach(&donate).is_none() {
                lease.shared.member_loop_while(state.cfg.entry, &donate);
            }
            backoff.reset();
        } else if backoff.is_completed() {
            // Fully idle (no queue, no crews): sleep instead of burning
            // the core — a long-lived server spends most of its life
            // here. 200 µs keeps dispatch latency negligible next to a
            // factorization.
            std::thread::sleep(Duration::from_micros(200));
        } else {
            backoff.snooze();
        }
    }
}

/// Lead one factorization request (either precision): register its crew,
/// drive the factorization, fulfill the typed handle.
fn lead_factor<S: Scalar>(
    state: &ServerState,
    id: u64,
    req: LuRequest<S>,
    submitted: Instant,
    jstate: Arc<JobState<JobResult<S>>>,
) {
    let LuRequest {
        mut a,
        kind,
        priority,
        deadline,
        bo,
        bi,
        client,
        driver,
    } = req;
    let bo = bo.unwrap_or(state.cfg.bo);
    let bi = bi.unwrap_or(state.cfg.bi);
    let deadline = deadline.map(|d| submitted + d);
    // A request cancelled (or expired) while still queued costs nothing;
    // the pool stays fully available to the rest of the batch. A
    // malformed problem (rectangular Cholesky) is rejected the same way
    // rather than poisoning a crew.
    let shape_check = kind.validate(a.rows(), a.cols());
    let dead_on_arrival = jstate.cancel.load(Ordering::Acquire)
        || deadline.is_some_and(|d| Instant::now() >= d)
        || shape_check.is_err();
    if dead_on_arrival {
        let shape_err = match shape_check {
            Err(e) => {
                eprintln!("serve: request {id} rejected: {e}");
                Some(FactorError::Unsupported(e.to_string()))
            }
            Ok(()) => None,
        };
        let secs = submitted.elapsed().as_secs_f64();
        let result = JobResult {
            id,
            kind,
            a,
            ipiv: Vec::new(),
            tau: Vec::new(),
            cols_done: 0,
            cancelled: true,
            secs,
            error: shape_err,
        };
        if capture::active() {
            // Dead-on-arrival outcome is wall-clock-shaped (cancel races
            // the pop, deadlines expire in queue): recorded so replay can
            // skip certification for it, never certified (§16.4).
            capture::record_result(id, factor_digest(&result), 0, true, result.error.is_some());
        }
        complete(&jstate, result);
        return;
    }
    let (m, n) = (a.rows(), a.cols());
    let mut crew = Crew::with_arena(Arc::clone(&state.arena));
    let initial_cost = kind.remaining_cost_prec::<S>(&state.cfg.hw, m, n, 0, bo, bi);
    let lease = Arc::new(Lease::new(id, priority, crew.shared(), initial_cost));
    state.registry.register(Arc::clone(&lease));
    if capture::active() {
        capture::record(
            DecisionKind::LeaseGrant,
            id,
            u64::from(priority),
            initial_cost.to_bits(),
        );
    }
    let dcfg = driver::DriveCfg {
        params: &state.cfg.params,
        hw: &state.cfg.hw,
        bo,
        bi,
        kind,
        lease: &lease,
        cancel: &jstate.cancel,
        deadline,
        client,
        driver,
    };
    let out = driver::drive(&mut crew, a.view_mut(), &dcfg);
    // Withdraw before disbanding: floaters leave at the epoch bump, and
    // disband waits for the stragglers, so the crew's workers are back
    // in their serve loops before the result is published.
    state.registry.unregister(id);
    if capture::active() {
        capture::record(
            DecisionKind::LeaseRevoke,
            id,
            out.cols_done as u64
                | (u64::from(out.cancelled) << 32)
                | (u64::from(lease.is_poisoned()) << 33),
            0,
        );
    }
    crew.disband();
    let secs = submitted.elapsed().as_secs_f64();
    let result = JobResult {
        id,
        kind,
        a,
        ipiv: out.ipiv,
        tau: out.tau,
        cols_done: out.cols_done,
        cancelled: out.cancelled,
        secs,
        error: out.error,
    };
    if capture::active() {
        capture::record_result(
            id,
            factor_digest(&result),
            result.cols_done as u32,
            result.cancelled,
            result.error.is_some(),
        );
    }
    complete(&jstate, result);
}

/// Dispatch a popped bundle to the typed leader matching its bucket's
/// precision code.
fn lead_small_bundle(key: SmallKey, members: Vec<StagedSmall>) {
    if key.prec == bundle::prec_code::<f32>() {
        lead_small::<f32>(members);
    } else {
        lead_small::<f64>(members);
    }
}

/// Lead one interleaved bundle (DESIGN.md §18): pack the members'
/// matrices problem-major, run the register-resident kernel once, and
/// fulfill every member's typed handle. No crew, no lease, no packing
/// arena — the whole point of the fast path is skipping that machinery,
/// so the registry never sees these requests and large requests keep
/// their leases (and floaters) while bundles drain.
///
/// Capture (DESIGN.md §16): each member's `Submit` was already recorded
/// at admission; bundle formation is recorded here as the environmental
/// [`DecisionKind::BundleForm`] (composition is timing-shaped, never
/// certified), and the per-member result digest closes the record. The
/// invariant subsequence of a bundled request is therefore just
/// `Submit` — deterministic however the bundles happen to form, because
/// every composition factors each lane bitwise-identically.
fn lead_small<S: Scalar>(members: Vec<StagedSmall>) {
    let mut live: Vec<SmallReq<S>> = Vec::with_capacity(members.len());
    for m in members {
        let req = match m.payload.downcast::<SmallReq<S>>() {
            Ok(r) => *r,
            // Unreachable by construction (the bucket key fixes the
            // precision); a panic routes every member through the serve
            // loop's abort recovery rather than hanging a waiter.
            Err(_) => panic!("small bundle: payload precision does not match bucket"),
        };
        // A member cancelled while staged costs nothing — complete it
        // out of the bundle, like a queued per-problem cancel.
        if req.jstate.cancel.load(Ordering::Acquire) {
            let secs = req.submitted.elapsed().as_secs_f64();
            let result = JobResult {
                id: req.id,
                kind: FactorKind::Lu,
                a: req.a,
                ipiv: Vec::new(),
                tau: Vec::new(),
                cols_done: 0,
                cancelled: true,
                secs,
                error: None,
            };
            if capture::active() {
                capture::record_result(req.id, factor_digest(&result), 0, true, false);
            }
            complete(&req.jstate, result);
        } else {
            live.push(req);
        }
    }
    if live.is_empty() {
        return;
    }
    let n = live[0].a.cols();
    if capture::active() {
        // One environmental record per member: b packs
        // n | prec << 8 | live << 16 | slot << 24; a names the bundle
        // anchor (first member) so a trace can regroup compositions.
        let anchor = live[0].id;
        for (slot, req) in live.iter().enumerate() {
            capture::record(
                DecisionKind::BundleForm,
                req.id,
                anchor,
                n as u64
                    | (u64::from(bundle::prec_code::<S>()) << 8)
                    | ((live.len() as u64) << 16)
                    | ((slot as u64) << 24),
            );
        }
    }
    let refs: Vec<&Mat<S>> = live.iter().map(|r| &r.a).collect();
    let mut bundle_mats = SmallBundle::pack(&refs);
    bundle_mats.factor();
    for (slot, req) in live.into_iter().enumerate() {
        let a = bundle_mats.lane_matrix(slot);
        let ipiv = bundle_mats.pivots(slot);
        // LAPACK info semantics, mirroring the blocked driver's
        // panel-health check: a zero pivot is recorded but the factors
        // still commit whole.
        let error = bundle_mats
            .zero_pivot_col(slot)
            .map(|col| FactorError::ExactlySingular { col });
        let secs = req.submitted.elapsed().as_secs_f64();
        let result = JobResult {
            id: req.id,
            kind: FactorKind::Lu,
            a,
            ipiv,
            tau: Vec::new(),
            cols_done: n,
            cancelled: false,
            secs,
            error,
        };
        if capture::active() {
            capture::record_result(
                req.id,
                factor_digest(&result),
                n as u32,
                false,
                result.error.is_some(),
            );
        }
        complete(&req.jstate, result);
    }
}

/// Lead one solve request: register a crew lease priced at the chosen
/// precision's flop rate, run the precision-selected solve (factor stage
/// on the crew, refinement on the leader), fulfill the handle. Trace
/// spans are tagged `req{id}:solve:{prec}`.
fn lead_solve(
    state: &ServerState,
    id: u64,
    req: SolveRequest,
    submitted: Instant,
    jstate: Arc<JobState<SolveJobResult>>,
) {
    let SolveRequest {
        a,
        b,
        prec,
        priority,
        deadline,
        bo,
        bi,
        client,
    } = req;
    let bo = bo.unwrap_or(state.cfg.bo);
    let bi = bi.unwrap_or(state.cfg.bi);
    let deadline = deadline.map(|d| submitted + d);
    let n = a.rows();
    let malformed = a.cols() != n || b.len() != n;
    let dead_on_arrival = jstate.cancel.load(Ordering::Acquire)
        || deadline.is_some_and(|d| Instant::now() >= d)
        || malformed;
    if dead_on_arrival {
        let shape_err = if malformed {
            let why = format!(
                "need square A + matching rhs, got {}x{} / {}",
                a.rows(),
                a.cols(),
                b.len()
            );
            eprintln!("serve: solve request {id} rejected: {why}");
            Some(FactorError::Unsupported(why))
        } else {
            None
        };
        let secs = submitted.elapsed().as_secs_f64();
        let result = SolveJobResult {
            id,
            prec,
            x: Vec::new(),
            refine_iters: 0,
            backward_error: f64::INFINITY,
            converged: false,
            cancelled: true,
            secs,
            error: shape_err,
        };
        if capture::active() {
            capture::record_result(id, solve_digest(&result), 0, true, result.error.is_some());
        }
        complete(&jstate, result);
        return;
    }
    let mut crew = Crew::with_arena(Arc::clone(&state.arena));
    // The factor stage dominates; price it at the chosen precision's
    // rate (mixed factors in f32).
    let rate = match prec {
        SolvePrec::F64 => 1.0,
        SolvePrec::F32 | SolvePrec::Mixed => f32::FLOP_RATE,
    };
    let initial_cost = FactorKind::Lu.remaining_cost(&state.cfg.hw, n, n, 0, bo, bi) / rate;
    let lease = Arc::new(Lease::new(id, priority, crew.shared(), initial_cost));
    state.registry.register(Arc::clone(&lease));
    if capture::active() {
        capture::record(
            DecisionKind::LeaseGrant,
            id,
            u64::from(priority),
            initial_cost.to_bits(),
        );
    }
    let tag = match client {
        Some(c) => format!("req{id}@c{c}:solve:{}", prec.name()),
        None => format!("req{id}:solve:{}", prec.name()),
    };
    let hw = state.cfg.hw;
    let lease2 = Arc::clone(&lease);
    let cancel2 = &jstate.cancel;
    let crew_shared = crew.shared();
    let prev_stolen = AtomicU64::new(0);
    let prev_tiles = AtomicU64::new(0);
    // Deadline enforcement mirrors `drive`: every factor checkpoint
    // folds an expired deadline into the cancel flag, which the factor
    // stage polls between panel steps and the refiner polls between
    // sweeps. (A deadline expiring inside a single O(n²) refinement
    // sweep is caught at the next sweep boundary.) Steal pressure is
    // fed back the same way (DESIGN.md §13).
    let checkpoint = move |k: usize| {
        let rem = FactorKind::Lu.remaining_cost(&hw, n, n, k, bo, bi) / rate;
        lease2.set_remaining(rem);
        let (ds, dt) = lease2.fold_steal_delta(&crew_shared, &prev_stolen, &prev_tiles);
        if capture::active() {
            capture::record(DecisionKind::Checkpoint, id, k as u64, rem.to_bits());
            capture::record(
                DecisionKind::StealDelta,
                id,
                k as u64,
                capture::pack_delta(ds, dt),
            );
        }
        if let Some(d) = deadline {
            if Instant::now() >= d && !cancel2.swap(true, Ordering::Release) {
                capture::record(DecisionKind::EtTrigger, id, k as u64, 1);
            }
        }
    };
    let ctl = SolveCtl {
        cancel: Some(cancel2),
        tag: Some(&tag),
        on_checkpoint: Some(&checkpoint),
    };
    let out = crate::solve::solve_system_ctl(
        &mut crew,
        &state.cfg.params,
        prec,
        &a,
        &b,
        bo,
        bi,
        &ctl,
    );
    state.registry.unregister(id);
    if capture::active() {
        // Solves commit whole (no partial column prefix): cols_done in
        // the revoke record is `n` on a clean run, 0 on a cancel.
        let done = if out.cancelled { 0u64 } else { n as u64 };
        capture::record(
            DecisionKind::LeaseRevoke,
            id,
            done | (u64::from(out.cancelled) << 32) | (u64::from(lease.is_poisoned()) << 33),
            0,
        );
    }
    crew.disband();
    let secs = submitted.elapsed().as_secs_f64();
    let result = SolveJobResult {
        id,
        prec,
        x: out.x,
        refine_iters: out.refine_iters,
        backward_error: out.backward_error,
        converged: out.converged,
        cancelled: out.cancelled,
        secs,
        error: out.error,
    };
    if capture::active() {
        let done = if result.cancelled { 0 } else { n as u32 };
        capture::record_result(
            id,
            solve_digest(&result),
            done,
            result.cancelled,
            result.error.is_some(),
        );
    }
    complete(&jstate, result);
}

fn complete<R>(jstate: &JobState<R>, result: R) {
    *jstate.done.lock().unwrap_or_else(|e| e.into_inner()) = Some(result);
    jstate.cv.notify_all();
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::matrix::naive;

    fn tiny_cfg(workers: usize) -> ServeConfig {
        ServeConfig {
            workers,
            bo: 16,
            bi: 4,
            params: BlisParams::tiny(),
            ..Default::default()
        }
    }

    fn qj(id: u64, priority: u8) -> QueuedJob {
        QueuedJob {
            id,
            seq: id,
            priority,
            run: Box::new(|_: &ServerState| {}),
            abort: Box::new(|_| {}),
        }
    }

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        let mut heap = BinaryHeap::new();
        heap.push(qj(0, 1));
        heap.push(qj(1, 3));
        heap.push(qj(2, 1));
        heap.push(qj(3, 3));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|j| j.id)).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn single_worker_batch_completes_in_priority_order_of_results() {
        let server = LuServer::new(tiny_cfg(1));
        let mats: Vec<Matrix> = (0..3)
            .map(|i| Matrix::random(24 + 8 * i, 24 + 8 * i, i as u64))
            .collect();
        let originals = mats.clone();
        let reqs: Vec<LuRequest> = mats.into_iter().map(LuRequest::new).collect();
        let results = server.factorize_batch(reqs);
        assert_eq!(results.len(), 3);
        for (res, a0) in results.iter().zip(&originals) {
            assert!(!res.cancelled);
            assert_eq!(res.cols_done, a0.rows());
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
        }
        server.shutdown();
    }

    #[test]
    fn multi_worker_mixed_batch_matches_reference_pivots() {
        let server = LuServer::new(tiny_cfg(3));
        let sizes = [40usize, 64, 32, 56, 48];
        let originals: Vec<Matrix> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Matrix::random(n, n, 100 + i as u64))
            .collect();
        let reqs: Vec<LuRequest> = originals
            .iter()
            .enumerate()
            .map(|(i, a)| LuRequest::new(a.clone()).with_priority((i % 3) as u8))
            .collect();
        let results = server.factorize_batch(reqs);
        for (res, a0) in results.iter().zip(&originals) {
            assert!(!res.cancelled, "req{} cancelled", res.id);
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
            // Scheduling must not change the math: pivots match the
            // sequential reference exactly.
            let mut g = a0.clone();
            let piv_ref = naive::lu(g.view_mut());
            assert_eq!(res.ipiv, piv_ref, "req{} pivots", res.id);
        }
        assert!(server.registry().is_empty());
        server.shutdown();
    }

    #[test]
    fn f32_and_f64_requests_share_one_queue() {
        let server = LuServer::new(tiny_cfg(2));
        let n = 48;
        let a64 = Matrix::random(n, n, 61);
        let a32 = Mat::<f32>::random(n, n, 62);
        let h64 = server.submit(LuRequest::new(a64.clone()));
        let h32 = server.submit(LuRequest::new(a32.clone()));
        let r64 = h64.wait();
        let r32 = h32.wait();
        assert!(!r64.cancelled && !r32.cancelled);
        assert_eq!(r64.cols_done, n);
        assert_eq!(r32.cols_done, n);
        let res64 = naive::lu_residual(&a64, &r64.a, &r64.ipiv);
        assert!(res64 < 1e-11, "f64 residual {res64}");
        let res32 = naive::lu_residual(&a32, &r32.a, &r32.ipiv);
        let tol32 = 8.0 * n as f64 * f32::EPSILON as f64;
        assert!(res32 < tol32, "f32 residual {res32} tol {tol32}");
        // Same seed stream: the f32 problem is the rounded image of the
        // f64 one, and its pivots still match the f32 reference.
        let mut g = a32.clone();
        let piv_ref = naive::lu(g.view_mut());
        assert_eq!(r32.ipiv, piv_ref, "f32 pivots");
        server.shutdown();
    }

    #[test]
    fn mixed_solve_request_reaches_f64_accuracy() {
        let server = LuServer::new(tiny_cfg(2));
        let n = 48;
        let a = Matrix::random_dd(n, 71);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.5) - 3.0).collect();
        let mut b = vec![0.0; n];
        for j in 0..n {
            for i in 0..n {
                b[i] += a[(i, j)] * x_true[j];
            }
        }
        let h = server.submit_solve(SolveRequest::new(a.clone(), b.clone()));
        let res = h.wait();
        assert!(!res.cancelled);
        assert!(res.converged, "backward error {}", res.backward_error);
        assert_eq!(res.prec, SolvePrec::Mixed);
        assert!(res.refine_iters >= 1);
        let tol = 2.0 * n as f64 * f64::EPSILON * 16.0;
        assert!(
            res.backward_error < tol,
            "solve backward error {} above {tol}",
            res.backward_error
        );
        for (xi, ti) in res.x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
        server.shutdown();
    }

    #[test]
    fn cancelled_queued_request_costs_nothing_and_pool_stays_usable() {
        let server = LuServer::new(tiny_cfg(2));
        // Cancel before any worker can finish it; whether it was popped
        // already or not, the result must come back flagged or complete —
        // and the server must keep serving afterwards.
        let victim = server.submit(LuRequest::new(Matrix::random(64, 64, 5)));
        victim.cancel();
        let res = victim.wait();
        assert!(res.cancelled || res.cols_done == 64);

        let a0 = Matrix::random(48, 48, 6);
        let ok = server.submit(LuRequest::new(a0.clone())).wait();
        assert!(!ok.cancelled);
        let r = naive::lu_residual(&a0, &ok.a, &ok.ipiv);
        assert!(r < 1e-11, "residual {r}");
        server.shutdown();
    }

    #[test]
    fn expired_deadline_yields_partial_result() {
        let server = LuServer::new(tiny_cfg(2));
        let h = server.submit(
            LuRequest::new(Matrix::random(64, 64, 7)).with_deadline(Duration::from_secs(0)),
        );
        let res = h.wait();
        assert!(res.cancelled);
        assert!(res.cols_done < 64);
        server.shutdown();
    }

    #[test]
    fn convenience_batch_entry_point() {
        let mats: Vec<Matrix> = (0..4).map(|i| Matrix::random(32, 32, 50 + i)).collect();
        let originals = mats.clone();
        let results = factorize_batch(mats, &tiny_cfg(2));
        assert_eq!(results.len(), 4);
        for (res, a0) in results.iter().zip(&originals) {
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
        }
    }

    #[test]
    fn repeated_batches_reach_zero_allocation_steady_state() {
        // One worker => one leader at a time => deterministic lease
        // pattern: after the first batch has warmed the shared arena, a
        // second batch of identical shapes must not allocate.
        let server = LuServer::new(tiny_cfg(1));
        let batch = |seed: u64| -> Vec<LuRequest> {
            (0..3)
                .map(|i| LuRequest::new(Matrix::random(40, 40, seed + i)))
                .collect()
        };
        let first = server.factorize_batch(batch(1));
        assert!(first.iter().all(|r| !r.cancelled));
        let warm = server.arena_stats();
        assert!(warm.allocations > 0);
        let second = server.factorize_batch(batch(100));
        assert!(second.iter().all(|r| !r.cancelled));
        let steady = server.arena_stats();
        assert_eq!(
            warm.allocations, steady.allocations,
            "steady-state serving allocated packed buffers"
        );
        assert!(steady.leases > warm.leases);
        server.shutdown();
    }

    #[test]
    fn mixed_kind_batch_shares_one_queue() {
        let server = LuServer::new(tiny_cfg(2));
        let n = 40;
        let a_lu = Matrix::random(n, n, 71);
        let a_ch = Matrix::random_spd(n, 72);
        let a_qr = Matrix::random(n + 8, n, 73);
        let handles = vec![
            server.submit(LuRequest::new(a_lu.clone())),
            server.submit(LuRequest::new(a_ch.clone()).with_kind(FactorKind::Chol)),
            server.submit(LuRequest::new(a_qr.clone()).with_kind(FactorKind::Qr)),
        ];
        let results: Vec<JobResult> = handles.into_iter().map(|h| h.wait()).collect();
        for r in &results {
            assert!(!r.cancelled, "req{} ({}) cancelled", r.id, r.kind.name());
            assert_eq!(r.cols_done, n, "req{}", r.id);
        }
        assert_eq!(results[0].kind, FactorKind::Lu);
        let r_lu = crate::matrix::naive::lu_residual(&a_lu, &results[0].a, &results[0].ipiv);
        assert!(r_lu < 1e-11, "lu residual {r_lu}");
        assert_eq!(results[1].kind, FactorKind::Chol);
        let r_ch = crate::matrix::naive::chol_residual(&a_ch, &results[1].a);
        assert!(r_ch < 1e-11, "chol residual {r_ch}");
        assert_eq!(results[2].kind, FactorKind::Qr);
        let r_qr = crate::matrix::naive::qr_residual(&a_qr, &results[2].a, &results[2].tau);
        assert!(r_qr < 1e-11, "qr residual {r_qr}");
        server.shutdown();
    }

    #[test]
    fn rectangular_cholesky_request_is_rejected_cleanly() {
        let server = LuServer::new(tiny_cfg(1));
        let h =
            server.submit(LuRequest::new(Matrix::random(16, 24, 1)).with_kind(FactorKind::Chol));
        let res = h.wait();
        assert!(res.cancelled);
        assert_eq!(res.cols_done, 0);
        // The server keeps serving after the rejection.
        let a0 = Matrix::random(24, 24, 2);
        let ok = server.submit(LuRequest::new(a0.clone())).wait();
        assert!(!ok.cancelled);
        server.shutdown();
    }

    #[test]
    fn expired_solve_deadline_is_cancelled() {
        let server = LuServer::new(tiny_cfg(1));
        let n = 48;
        let a = Matrix::random_dd(n, 81);
        let b = vec![1.0; n];
        let h = server
            .submit_solve(SolveRequest::new(a, b).with_deadline(Duration::from_secs(0)));
        let res = h.wait();
        assert!(res.cancelled);
        assert!(!res.converged);
        server.shutdown();
    }

    #[test]
    fn malformed_solve_request_is_rejected_cleanly() {
        let server = LuServer::new(tiny_cfg(1));
        // rhs length mismatch
        let h = server.submit_solve(SolveRequest::new(Matrix::random(16, 16, 1), vec![1.0; 8]));
        let res = h.wait();
        assert!(res.cancelled);
        assert!(!res.converged);
        server.shutdown();
    }

    #[test]
    fn interleaved_batch_matches_per_problem_reference_bitwise() {
        let server = LuServer::new(ServeConfig {
            interleave: true,
            ..tiny_cfg(2)
        });
        let n = 12;
        let originals: Vec<Matrix> = (0..9).map(|i| Matrix::random(n, n, 300 + i)).collect();
        let reqs: Vec<LuRequest> = originals.iter().map(|a| LuRequest::new(a.clone())).collect();
        let results = server.factorize_batch(reqs);
        for (res, a0) in results.iter().zip(&originals) {
            assert!(!res.cancelled, "req{}", res.id);
            assert_eq!(res.cols_done, n);
            assert!(res.error.is_none(), "req{}: {:?}", res.id, res.error);
            let mut f = a0.clone();
            let ipiv = crate::lu::lu_unblocked(f.view_mut());
            assert_eq!(res.ipiv, ipiv, "req{} pivots", res.id);
            assert_eq!(res.a.data(), f.data(), "req{} factors", res.id);
        }
        assert_eq!(server.staged_small(), 0);
        // The fast path never touched the lease machinery or the arena.
        assert!(server.registry().is_empty());
        assert_eq!(server.arena_stats().allocations, 0);
        server.shutdown();
    }

    #[test]
    fn interleaved_mixed_sizes_and_precisions_stay_separate() {
        // Alternating shapes and precisions must land in separate
        // buckets — a cross-shape bundle would panic in pack and come
        // back as an Internal error, so clean bitwise results certify
        // the grouping rule.
        let server = LuServer::new(ServeConfig {
            interleave: true,
            ..tiny_cfg(3)
        });
        let mut h64 = Vec::new();
        let mut ref64 = Vec::new();
        let mut h32 = Vec::new();
        let mut ref32 = Vec::new();
        for i in 0..10u64 {
            let n = if i % 2 == 0 { 8 } else { 13 };
            let a = Matrix::random(n, n, 500 + i);
            ref64.push(a.clone());
            h64.push(server.submit(LuRequest::new(a)));
            let a = Mat::<f32>::random(n, n, 900 + i);
            ref32.push(a.clone());
            h32.push(server.submit(LuRequest::new(a)));
        }
        for (h, a0) in h64.into_iter().zip(&ref64) {
            let res = h.wait();
            assert!(!res.cancelled && res.error.is_none());
            let mut f = a0.clone();
            let ipiv = crate::lu::lu_unblocked(f.view_mut());
            assert_eq!(res.ipiv, ipiv);
            assert_eq!(res.a.data(), f.data());
        }
        for (h, a0) in h32.into_iter().zip(&ref32) {
            let res = h.wait();
            assert!(!res.cancelled && res.error.is_none());
            let mut f = a0.clone();
            let ipiv = crate::lu::lu_unblocked(f.view_mut());
            assert_eq!(res.ipiv, ipiv);
            assert_eq!(res.a.data(), f.data());
        }
        server.shutdown();
    }

    #[test]
    fn interleaved_singular_member_reports_exactly_singular() {
        let server = LuServer::new(ServeConfig {
            interleave: true,
            ..tiny_cfg(1)
        });
        let zero = Matrix::zeros(6, 6);
        let good = Matrix::random_dd(6, 44);
        let hz = server.submit(LuRequest::new(zero));
        let hg = server.submit(LuRequest::new(good.clone()));
        let rz = hz.wait();
        assert!(!rz.cancelled, "LAPACK info semantics: completes");
        assert_eq!(rz.cols_done, 6);
        assert!(
            matches!(rz.error, Some(FactorError::ExactlySingular { col: 0 })),
            "{:?}",
            rz.error
        );
        let rg = hg.wait();
        assert!(rg.error.is_none());
        let r = naive::lu_residual(&good, &rg.a, &rg.ipiv);
        assert!(r < 1e-12, "residual {r}");
        server.shutdown();
    }

    #[test]
    fn interleaved_cancel_while_staged_is_clean() {
        let server = LuServer::new(ServeConfig {
            interleave: true,
            ..tiny_cfg(1)
        });
        let h = server.submit(LuRequest::new(Matrix::random(10, 10, 3)));
        h.cancel();
        let res = h.wait();
        // Whether the cancel won the race to the bundle leader or not,
        // the waiter gets a coherent result and the server keeps going.
        assert!(res.cancelled || res.cols_done == 10);
        let a0 = Matrix::random(10, 10, 4);
        let ok = server.submit(LuRequest::new(a0.clone())).wait();
        assert!(!ok.cancelled);
        server.shutdown();
    }

    #[test]
    fn results_return_in_submission_order() {
        let server = LuServer::new(tiny_cfg(2));
        let reqs: Vec<LuRequest> = (0..4)
            .map(|i| LuRequest::new(Matrix::random(24, 24, i)).with_priority((3 - i) as u8))
            .collect();
        let results = server.factorize_batch(reqs);
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        server.shutdown();
    }
}
