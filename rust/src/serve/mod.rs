//! §serve — the **batched multi-problem factorization scheduler**
//! (DESIGN.md §10).
//!
//! The paper's Worker-Sharing and Early-Termination mechanisms move
//! threads between the two branches of *one* look-ahead factorization.
//! This layer generalizes both across *problems*: an [`LuServer`] accepts
//! a queue of factorization requests (mixed sizes, priorities, optional
//! deadlines — and since the factorization-family refactor, mixed
//! [`FactorKind`]s: `Lu | Chol | Qr` share one priority queue, one crew
//! registry, and one cost model) and multiplexes them over a single
//! [`Pool`].
//!
//! Scheduling model — every pool worker runs the same `serve_loop`:
//!
//! 1. **Lead.** Pop the highest-priority queued request and drive its
//!    factorization to completion ([`driver::drive`]), leading a
//!    malleable [`Crew`] registered in the [`CrewRegistry`].
//! 2. **Float.** If the queue is empty, enlist as a member of the most
//!    starved in-flight crew (priority- and remaining-FLOPs-aware, using
//!    [`crate::sim::costmodel`] estimates) under a revocable lease
//!    ([`crate::pool::CrewShared::member_loop_while`]). The lease is
//!    revoked — at a job boundary, so no chunk is lost or re-run — when
//!    the registry's picture changes or new work is queued.
//!
//! Thus any finished or blocked problem's workers flow to whichever
//! problem is furthest behind: the WS rule lifted from two branches to N
//! problems. Early Termination generalizes too: [`JobHandle::cancel`]
//! (or an expired deadline) stops a request at its next panel
//! checkpoint, leaving a clean factored prefix and returning its crew to
//! the pool.
//!
//! Every kernel span a leader emits is tagged `req{id}:{kind}`, so
//! [`crate::trace::ascii_gantt_requests`] can render one Gantt lane per
//! problem, labeled with its factorization kind.

pub mod driver;
pub mod registry;

pub use registry::{CrewRegistry, Lease};

use crate::blis::{BlisParams, PackArena};
use crate::factor::FactorKind;
use crate::matrix::Matrix;
use crate::pool::{Crew, EntryPolicy, Pool, TaskHandle};
use crate::sim::HwModel;
use crossbeam_utils::Backoff;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Copy, Clone, Debug)]
pub struct ServeConfig {
    /// Pool workers serving the queue (each alternates between leading a
    /// request and floating into starved crews).
    pub workers: usize,
    /// Default outer block size for requests that don't override it.
    pub bo: usize,
    /// Default inner (panel) block size.
    pub bi: usize,
    /// BLIS blocking parameters shared by every request's kernels.
    pub params: BlisParams,
    /// How floating workers enter an in-flight kernel.
    pub entry: EntryPolicy,
    /// Cost model used for remaining-work estimates.
    pub hw: HwModel,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            bo: 64,
            bi: 16,
            params: BlisParams::default(),
            entry: EntryPolicy::JobBoundary,
            hw: HwModel::default(),
        }
    }
}

/// One factorization request (of any [`FactorKind`] — the name predates
/// the factorization-family refactor).
pub struct LuRequest {
    /// The matrix to factorize (consumed; returned in the result).
    pub a: Matrix,
    /// Which factorization to run (`Lu` by default).
    pub kind: FactorKind,
    /// Higher runs first and attracts floaters more strongly.
    pub priority: u8,
    /// Budget after which the request is ET-cancelled.
    pub deadline: Option<Duration>,
    /// Outer block-size override (server default when `None`).
    pub bo: Option<usize>,
    /// Inner block-size override.
    pub bi: Option<usize>,
}

impl LuRequest {
    /// A default-priority LU request with server-default block sizes.
    pub fn new(a: Matrix) -> Self {
        Self {
            a,
            kind: FactorKind::Lu,
            priority: 0,
            deadline: None,
            bo: None,
            bi: None,
        }
    }

    /// Select the factorization kind (Cholesky requests must carry a
    /// square SPD matrix; a rectangular one is rejected at lead time and
    /// comes back `cancelled`).
    pub fn with_kind(mut self, kind: FactorKind) -> Self {
        self.kind = kind;
        self
    }

    /// Set the scheduling priority (higher runs first).
    pub fn with_priority(mut self, p: u8) -> Self {
        self.priority = p;
        self
    }

    /// Set the wall-clock budget after which the request is cancelled.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Override the server's default outer/inner block sizes.
    pub fn with_blocks(mut self, bo: usize, bi: usize) -> Self {
        self.bo = Some(bo);
        self.bi = Some(bi);
        self
    }
}

/// Completed (or cancelled) request.
#[derive(Debug)]
pub struct JobResult {
    /// Request id assigned at submission.
    pub id: u64,
    /// The factorization that ran.
    pub kind: FactorKind,
    /// The matrix, now holding the factors (a clean factored prefix of
    /// `cols_done` columns if the request was cancelled).
    pub a: Matrix,
    /// Absolute pivots for the committed columns (LU only).
    pub ipiv: Vec<usize>,
    /// Householder scalar factors for the committed columns (QR only).
    pub tau: Vec<f64>,
    /// Columns fully factorized and committed.
    pub cols_done: usize,
    /// Whether the request was cancelled (by handle, deadline, or a
    /// malformed problem, e.g. a rectangular Cholesky).
    pub cancelled: bool,
    /// Wall seconds from submission to completion.
    pub secs: f64,
}

struct JobState {
    done: Mutex<Option<JobResult>>,
    cv: Condvar,
    cancel: AtomicBool,
}

/// Handle returned by [`LuServer::submit`].
pub struct JobHandle {
    id: u64,
    state: Arc<JobState>,
}

impl JobHandle {
    /// The request id (matches [`JobResult::id`] and trace tags).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request-level early termination: drop the job if still queued, or
    /// stop it at its next panel checkpoint. The crew it occupied
    /// returns to the pool either way.
    pub fn cancel(&self) {
        self.state.cancel.store(true, Ordering::Release);
    }

    /// Whether the result is ready (non-blocking).
    pub fn is_done(&self) -> bool {
        self.state.done.lock().unwrap().is_some()
    }

    /// Block until the request completes (or is cancelled) and take the
    /// result.
    pub fn wait(self) -> JobResult {
        let mut slot = self.state.done.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.state.cv.wait(slot).unwrap();
        }
    }
}

struct QueuedJob {
    id: u64,
    seq: u64,
    priority: u8,
    kind: FactorKind,
    a: Matrix,
    bo: usize,
    bi: usize,
    deadline: Option<Instant>,
    submitted: Instant,
    state: Arc<JobState>,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}

impl Eq for QueuedJob {}

impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedJob {
    /// Max-heap key: priority first, then FIFO within a priority class.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

struct ServerState {
    queue: Mutex<BinaryHeap<QueuedJob>>,
    /// Mirror of `queue.len()` readable without the lock (floaters poll
    /// it from inside crew job waits).
    queued: AtomicUsize,
    registry: CrewRegistry,
    stop: AtomicBool,
    cfg: ServeConfig,
    /// Packing arena shared by every request's crew: once the largest
    /// request shape has been served, later factorizations lease their
    /// packed buffers without allocating (DESIGN.md §9).
    arena: Arc<PackArena>,
}

impl ServerState {
    fn pop(&self) -> Option<QueuedJob> {
        let mut q = self.queue.lock().unwrap();
        let job = q.pop();
        self.queued.store(q.len(), Ordering::Release);
        job
    }
}

/// The batched multi-problem LU server (module docs above).
pub struct LuServer {
    pool: Pool,
    state: Arc<ServerState>,
    loops: Mutex<Vec<TaskHandle>>,
    next_id: AtomicU64,
}

impl LuServer {
    /// Spawn `cfg.workers` pool workers, each running a serve loop.
    pub fn new(cfg: ServeConfig) -> Self {
        let pool = Pool::new(cfg.workers.max(1));
        let state = Arc::new(ServerState {
            queue: Mutex::new(BinaryHeap::new()),
            queued: AtomicUsize::new(0),
            registry: CrewRegistry::new(),
            stop: AtomicBool::new(false),
            cfg,
            arena: Arc::new(PackArena::new()),
        });
        let loops = pool.broadcast(|_w| {
            let st = Arc::clone(&state);
            move || serve_loop(&st)
        });
        Self {
            pool,
            state,
            loops: Mutex::new(loops),
            next_id: AtomicU64::new(0),
        }
    }

    /// Number of pool workers serving requests.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// In-flight problem registry (exposed for tests and introspection).
    pub fn registry(&self) -> &CrewRegistry {
        &self.state.registry
    }

    /// Statistics of the packing arena shared by all requests' crews
    /// (steady-state serving must stop allocating — DESIGN.md §9).
    pub fn arena_stats(&self) -> crate::blis::ArenaStats {
        self.state.arena.stats()
    }

    /// Enqueue a request; returns immediately with a handle.
    pub fn submit(&self, req: LuRequest) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let state = Arc::new(JobState {
            done: Mutex::new(None),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
        });
        let now = Instant::now();
        let job = QueuedJob {
            id,
            seq: id,
            priority: req.priority,
            kind: req.kind,
            a: req.a,
            bo: req.bo.unwrap_or(self.state.cfg.bo),
            bi: req.bi.unwrap_or(self.state.cfg.bi),
            deadline: req.deadline.map(|d| now + d),
            submitted: now,
            state: Arc::clone(&state),
        };
        {
            // Stop-check and push under one lock: shutdown() also sets
            // `stop` under this lock, so a job can never slip into the
            // queue after the serve loops were told to drain and exit
            // (its waiter would hang forever).
            let mut q = self.state.queue.lock().unwrap();
            assert!(
                !self.state.stop.load(Ordering::Acquire),
                "LuServer::submit after shutdown"
            );
            q.push(job);
            self.state.queued.store(q.len(), Ordering::Release);
        }
        JobHandle { id, state }
    }

    /// Submit a whole batch and wait for every result (returned in
    /// submission order).
    pub fn factorize_batch(&self, reqs: Vec<LuRequest>) -> Vec<JobResult> {
        let handles: Vec<JobHandle> = reqs.into_iter().map(|r| self.submit(r)).collect();
        handles.into_iter().map(|h| h.wait()).collect()
    }

    /// Stop accepting work, drain already-queued requests, and join the
    /// serve loops. Called automatically on drop.
    pub fn shutdown(&self) {
        {
            // Under the queue lock — see the pairing note in `submit`.
            let _q = self.state.queue.lock().unwrap();
            self.state.stop.store(true, Ordering::Release);
        }
        for h in self.loops.lock().unwrap().drain(..) {
            h.wait();
        }
    }
}

impl Drop for LuServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One-call batch entry point: factorize all matrices on a fresh server,
/// returning results in input order.
pub fn factorize_batch(mats: Vec<Matrix>, cfg: &ServeConfig) -> Vec<JobResult> {
    let server = LuServer::new(*cfg);
    let reqs: Vec<LuRequest> = mats.into_iter().map(LuRequest::new).collect();
    let out = server.factorize_batch(reqs);
    server.shutdown();
    out
}

/// One pool worker's scheduling loop: lead the highest-priority queued
/// request, else float into the most starved in-flight crew, else wait.
fn serve_loop(state: &ServerState) {
    let backoff = Backoff::new();
    loop {
        if let Some(job) = state.pop() {
            let jstate = Arc::clone(&job.state);
            let id = job.id;
            let kind = job.kind;
            // A panicking request must not wedge its waiter or leak its
            // registry entry (that would strand floaters on a dead crew).
            let led =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| lead_job(state, job)));
            if led.is_err() {
                state.registry.unregister(id);
                eprintln!("serve: request {id} panicked; reported as cancelled");
                complete(
                    &jstate,
                    JobResult {
                        id,
                        kind,
                        a: Matrix::zeros(0, 0),
                        ipiv: Vec::new(),
                        tau: Vec::new(),
                        cols_done: 0,
                        cancelled: true,
                        secs: 0.0,
                    },
                );
            }
            backoff.reset();
            continue;
        }
        if state.stop.load(Ordering::Acquire) && state.queued.load(Ordering::Acquire) == 0 {
            break;
        }
        let e0 = state.registry.epoch();
        if let Some(lease) = state.registry.most_starved() {
            // Donate this worker until the picture changes: the crew
            // closes, a problem arrives or finishes, queued work appears,
            // or the server stops.
            lease.shared.member_loop_while(state.cfg.entry, || {
                state.registry.epoch() == e0
                    && state.queued.load(Ordering::Acquire) == 0
                    && !state.stop.load(Ordering::Acquire)
            });
            backoff.reset();
        } else if backoff.is_completed() {
            // Fully idle (no queue, no crews): sleep instead of burning
            // the core — a long-lived server spends most of its life
            // here. 200 µs keeps dispatch latency negligible next to a
            // factorization.
            std::thread::sleep(Duration::from_micros(200));
        } else {
            backoff.snooze();
        }
    }
}

/// Lead one request: register its crew, drive the factorization, fulfill
/// the handle.
fn lead_job(state: &ServerState, job: QueuedJob) {
    let QueuedJob {
        id,
        kind,
        mut a,
        bo,
        bi,
        deadline,
        submitted,
        priority,
        state: jstate,
        ..
    } = job;
    // A request cancelled (or expired) while still queued costs nothing;
    // the pool stays fully available to the rest of the batch. A
    // malformed problem (rectangular Cholesky) is rejected the same way
    // rather than poisoning a crew.
    let shape_check = kind.validate(a.rows(), a.cols());
    let dead_on_arrival = jstate.cancel.load(Ordering::Acquire)
        || deadline.is_some_and(|d| Instant::now() >= d)
        || shape_check.is_err();
    if dead_on_arrival {
        if let Err(e) = shape_check {
            eprintln!("serve: request {id} rejected: {e}");
        }
        let secs = submitted.elapsed().as_secs_f64();
        complete(
            &jstate,
            JobResult {
                id,
                kind,
                a,
                ipiv: Vec::new(),
                tau: Vec::new(),
                cols_done: 0,
                cancelled: true,
                secs,
            },
        );
        return;
    }
    let (m, n) = (a.rows(), a.cols());
    let mut crew = Crew::with_arena(Arc::clone(&state.arena));
    let lease = Arc::new(Lease::new(
        id,
        priority,
        crew.shared(),
        kind.remaining_cost(&state.cfg.hw, m, n, 0, bo, bi),
    ));
    state.registry.register(Arc::clone(&lease));
    let dcfg = driver::DriveCfg {
        params: &state.cfg.params,
        hw: &state.cfg.hw,
        bo,
        bi,
        kind,
        lease: &lease,
        cancel: &jstate.cancel,
        deadline,
    };
    let out = driver::drive(&mut crew, a.view_mut(), &dcfg);
    // Withdraw before disbanding: floaters leave at the epoch bump, and
    // disband waits for the stragglers, so the crew's workers are back
    // in their serve loops before the result is published.
    state.registry.unregister(id);
    crew.disband();
    let secs = submitted.elapsed().as_secs_f64();
    complete(
        &jstate,
        JobResult {
            id,
            kind,
            a,
            ipiv: out.ipiv,
            tau: out.tau,
            cols_done: out.cols_done,
            cancelled: out.cancelled,
            secs,
        },
    );
}

fn complete(jstate: &JobState, result: JobResult) {
    *jstate.done.lock().unwrap() = Some(result);
    jstate.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::naive;

    fn tiny_cfg(workers: usize) -> ServeConfig {
        ServeConfig {
            workers,
            bo: 16,
            bi: 4,
            params: BlisParams::tiny(),
            ..Default::default()
        }
    }

    fn qj(id: u64, priority: u8) -> QueuedJob {
        QueuedJob {
            id,
            seq: id,
            priority,
            kind: FactorKind::Lu,
            a: Matrix::zeros(1, 1),
            bo: 4,
            bi: 2,
            deadline: None,
            submitted: Instant::now(),
            state: Arc::new(JobState {
                done: Mutex::new(None),
                cv: Condvar::new(),
                cancel: AtomicBool::new(false),
            }),
        }
    }

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        let mut heap = BinaryHeap::new();
        heap.push(qj(0, 1));
        heap.push(qj(1, 3));
        heap.push(qj(2, 1));
        heap.push(qj(3, 3));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|j| j.id)).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn single_worker_batch_completes_in_priority_order_of_results() {
        let server = LuServer::new(tiny_cfg(1));
        let mats: Vec<Matrix> = (0..3)
            .map(|i| Matrix::random(24 + 8 * i, 24 + 8 * i, i as u64))
            .collect();
        let originals = mats.clone();
        let reqs: Vec<LuRequest> = mats.into_iter().map(LuRequest::new).collect();
        let results = server.factorize_batch(reqs);
        assert_eq!(results.len(), 3);
        for (res, a0) in results.iter().zip(&originals) {
            assert!(!res.cancelled);
            assert_eq!(res.cols_done, a0.rows());
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
        }
        server.shutdown();
    }

    #[test]
    fn multi_worker_mixed_batch_matches_reference_pivots() {
        let server = LuServer::new(tiny_cfg(3));
        let sizes = [40usize, 64, 32, 56, 48];
        let originals: Vec<Matrix> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Matrix::random(n, n, 100 + i as u64))
            .collect();
        let reqs: Vec<LuRequest> = originals
            .iter()
            .enumerate()
            .map(|(i, a)| LuRequest::new(a.clone()).with_priority((i % 3) as u8))
            .collect();
        let results = server.factorize_batch(reqs);
        for (res, a0) in results.iter().zip(&originals) {
            assert!(!res.cancelled, "req{} cancelled", res.id);
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
            // Scheduling must not change the math: pivots match the
            // sequential reference exactly.
            let mut g = a0.clone();
            let piv_ref = naive::lu(g.view_mut());
            assert_eq!(res.ipiv, piv_ref, "req{} pivots", res.id);
        }
        assert!(server.registry().is_empty());
        server.shutdown();
    }

    #[test]
    fn cancelled_queued_request_costs_nothing_and_pool_stays_usable() {
        let server = LuServer::new(tiny_cfg(2));
        // Cancel before any worker can finish it; whether it was popped
        // already or not, the result must come back flagged or complete —
        // and the server must keep serving afterwards.
        let victim = server.submit(LuRequest::new(Matrix::random(64, 64, 5)));
        victim.cancel();
        let res = victim.wait();
        assert!(res.cancelled || res.cols_done == 64);

        let a0 = Matrix::random(48, 48, 6);
        let ok = server.submit(LuRequest::new(a0.clone())).wait();
        assert!(!ok.cancelled);
        let r = naive::lu_residual(&a0, &ok.a, &ok.ipiv);
        assert!(r < 1e-11, "residual {r}");
        server.shutdown();
    }

    #[test]
    fn expired_deadline_yields_partial_result() {
        let server = LuServer::new(tiny_cfg(2));
        let h = server.submit(
            LuRequest::new(Matrix::random(64, 64, 7)).with_deadline(Duration::from_secs(0)),
        );
        let res = h.wait();
        assert!(res.cancelled);
        assert!(res.cols_done < 64);
        server.shutdown();
    }

    #[test]
    fn convenience_batch_entry_point() {
        let mats: Vec<Matrix> = (0..4).map(|i| Matrix::random(32, 32, 50 + i)).collect();
        let originals = mats.clone();
        let results = factorize_batch(mats, &tiny_cfg(2));
        assert_eq!(results.len(), 4);
        for (res, a0) in results.iter().zip(&originals) {
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
        }
    }

    #[test]
    fn repeated_batches_reach_zero_allocation_steady_state() {
        // One worker => one leader at a time => deterministic lease
        // pattern: after the first batch has warmed the shared arena, a
        // second batch of identical shapes must not allocate.
        let server = LuServer::new(tiny_cfg(1));
        let batch = |seed: u64| -> Vec<LuRequest> {
            (0..3)
                .map(|i| LuRequest::new(Matrix::random(40, 40, seed + i)))
                .collect()
        };
        let first = server.factorize_batch(batch(1));
        assert!(first.iter().all(|r| !r.cancelled));
        let warm = server.arena_stats();
        assert!(warm.allocations > 0);
        let second = server.factorize_batch(batch(100));
        assert!(second.iter().all(|r| !r.cancelled));
        let steady = server.arena_stats();
        assert_eq!(
            warm.allocations, steady.allocations,
            "steady-state serving allocated packed buffers"
        );
        assert!(steady.leases > warm.leases);
        server.shutdown();
    }

    #[test]
    fn mixed_kind_batch_shares_one_queue() {
        let server = LuServer::new(tiny_cfg(2));
        let n = 40;
        let a_lu = Matrix::random(n, n, 71);
        let a_ch = Matrix::random_spd(n, 72);
        let a_qr = Matrix::random(n + 8, n, 73);
        let handles = vec![
            server.submit(LuRequest::new(a_lu.clone())),
            server.submit(LuRequest::new(a_ch.clone()).with_kind(FactorKind::Chol)),
            server.submit(LuRequest::new(a_qr.clone()).with_kind(FactorKind::Qr)),
        ];
        let results: Vec<JobResult> = handles.into_iter().map(|h| h.wait()).collect();
        for r in &results {
            assert!(!r.cancelled, "req{} ({}) cancelled", r.id, r.kind.name());
            assert_eq!(r.cols_done, n, "req{}", r.id);
        }
        assert_eq!(results[0].kind, FactorKind::Lu);
        let r_lu = crate::matrix::naive::lu_residual(&a_lu, &results[0].a, &results[0].ipiv);
        assert!(r_lu < 1e-11, "lu residual {r_lu}");
        assert_eq!(results[1].kind, FactorKind::Chol);
        let r_ch = crate::matrix::naive::chol_residual(&a_ch, &results[1].a);
        assert!(r_ch < 1e-11, "chol residual {r_ch}");
        assert_eq!(results[2].kind, FactorKind::Qr);
        let r_qr = crate::matrix::naive::qr_residual(&a_qr, &results[2].a, &results[2].tau);
        assert!(r_qr < 1e-11, "qr residual {r_qr}");
        server.shutdown();
    }

    #[test]
    fn rectangular_cholesky_request_is_rejected_cleanly() {
        let server = LuServer::new(tiny_cfg(1));
        let h =
            server.submit(LuRequest::new(Matrix::random(16, 24, 1)).with_kind(FactorKind::Chol));
        let res = h.wait();
        assert!(res.cancelled);
        assert_eq!(res.cols_done, 0);
        // The server keeps serving after the rejection.
        let a0 = Matrix::random(24, 24, 2);
        let ok = server.submit(LuRequest::new(a0.clone())).wait();
        assert!(!ok.cancelled);
        server.shutdown();
    }

    #[test]
    fn results_return_in_submission_order() {
        let server = LuServer::new(tiny_cfg(2));
        let reqs: Vec<LuRequest> = (0..4)
            .map(|i| LuRequest::new(Matrix::random(24, 24, i)).with_priority((3 - i) as u8))
            .collect();
        let results = server.factorize_batch(reqs);
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        server.shutdown();
    }
}
