//! The network front-end of the serve layer: [`ServeDaemon`] exposes an
//! [`LuServer`] over TCP or a Unix-domain socket, speaking the
//! [`proto`](super::proto) wire protocol under the
//! [`admission`](super::admission) policy (DESIGN.md §14).
//!
//! Thread architecture — intake is fully decoupled from compute:
//!
//! ```text
//!  acceptor thread ──(new socket, client id)──▶ per-connection pair:
//!    reader thread: handshake → frames → admission → LuServer::submit
//!        │  bounded sync_channel (backpressure: a slow writer stalls
//!        ▼  the reader, which stalls the socket, which stalls the client)
//!    writer thread: polls job handles in completion order, encodes
//!                   responses, flushes, releases admission slots
//! ```
//!
//! The compute crews never touch a socket: a request enters the same
//! priority queue as in-process submissions, tagged with its connection
//! id (`req{id}@c{cid}:{kind}:{prec}` trace lanes).
//!
//! **Lifecycle.** [`ServeDaemon::drain`] implements graceful shutdown:
//! stop accepting connections, flip admission to `Draining` (new
//! requests get typed [`RejectCode::Draining`] rejects), let in-flight
//! work finish — or early-terminate it at the grace deadline through the
//! per-request [`CancelToken`]s — and flush every response before the
//! sockets close. Once the ledger settles (or the grace deadline
//! passes), a *hard stop* forces readers off even partially received
//! frames, so a client stalled mid-header cannot hold the drain open.
//! [`ServeDaemon::shutdown`] is drain plus joining every
//! thread and stopping the compute pool; the accounting invariant
//! `admitted == delivered + reaped` then holds exactly ([`DaemonStats`]).
//!
//! **Failure containment.** A client that disconnects mid-request is
//! *reaped*: its outstanding jobs are cancelled and awaited (so crew
//! leases unregister and arena buffers return — `free_buffers ==
//! allocations` survives any disconnect pattern), its admission slots
//! are released, and nothing else in the daemon notices.

use super::admission::{AdmissionCfg, AdmissionCtl, AdmissionStats};
use super::proto::{self, ReadEvent, RejectCode};
use super::{CancelToken, JobHandle, JobResult, LuRequest, LuServer, ServeConfig, SolveJobResult, SolveRequest};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Where the daemon listens (or a client connects).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BindAddr {
    /// TCP, e.g. `tcp:127.0.0.1:7070` (bind to port 0 for an ephemeral
    /// port, then read it back via [`ServeDaemon::local_addr`]).
    Tcp(String),
    /// Unix-domain socket path, e.g. `unix:/run/mlu.sock`.
    Unix(PathBuf),
}

impl BindAddr {
    /// Parse `unix:<path>`, `tcp:<host:port>`, or a bare `host:port`
    /// (treated as TCP).
    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err("empty unix socket path".into());
            }
            return Ok(Self::Unix(PathBuf::from(path)));
        }
        let hostport = s.strip_prefix("tcp:").unwrap_or(s);
        if hostport.is_empty() || !hostport.contains(':') {
            return Err(format!("bad listen address {s:?} (want unix:<path> or tcp:<host:port>)"));
        }
        Ok(Self::Tcp(hostport.to_string()))
    }
}

impl std::fmt::Display for BindAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Tcp(a) => write!(f, "tcp:{a}"),
            Self::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Daemon configuration: the compute layer's [`ServeConfig`], the
/// admission bounds, and the socket-level limits.
#[derive(Copy, Clone, Debug)]
pub struct NetConfig {
    /// Compute-side configuration (workers, block sizes, cost model).
    pub serve: ServeConfig,
    /// Admission bounds (pending queue, per-client quota, size cap).
    pub admission: AdmissionCfg,
    /// Largest accepted frame payload in bytes; larger frames are
    /// drained and rejected [`RejectCode::TooLarge`] without buffering.
    pub max_frame: usize,
    /// Socket read timeout — the poll granularity at which reader
    /// threads notice drain/shutdown. Smaller = faster drain response,
    /// more idle wakeups.
    pub read_timeout_ms: u64,
    /// Watchdog multiplier (DESIGN.md §15): a deadline-carrying request
    /// still unfinished after `deadline × watchdog_factor` is
    /// force-cancelled by the acceptor's poll, bounding the damage of a
    /// leader wedged *between* checkpoints (where the checkpoint
    /// deadline cut cannot see it). `0` disables the watchdog.
    /// Deadline-less requests are never watchdogged — nothing bounds
    /// how long they may legitimately run.
    pub watchdog_factor: u32,
    /// Floor on the watchdog trigger, so millisecond-scale deadlines do
    /// not turn scheduling jitter into spurious force-cancels.
    pub watchdog_min_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            serve: ServeConfig::default(),
            admission: AdmissionCfg::default(),
            max_frame: 64 << 20,
            read_timeout_ms: 25,
            watchdog_factor: 4,
            watchdog_min_ms: 250,
        }
    }
}

/// Counter snapshot from [`ServeDaemon::stats`]. After a completed
/// drain, `admission.admitted == delivered + reaped` — every admitted
/// request was answered exactly once or reaped against a vanished
/// client; nothing is silently dropped.
#[derive(Copy, Clone, Debug, Default)]
pub struct DaemonStats {
    /// Connections the acceptor handed to reader/writer pairs.
    pub conns_accepted: u64,
    /// Admission-control counters (admitted + typed rejections).
    pub admission: AdmissionStats,
    /// Responses (complete or ET-cancelled) flushed to live clients.
    pub delivered: u64,
    /// Admitted requests cancelled-and-awaited because their client
    /// disconnected before the response could be written.
    pub reaped: u64,
    /// Frames that failed to decode (bad magic/version/payload).
    pub malformed: u64,
    /// Frames whose announced payload exceeded `max_frame` (drained and
    /// rejected at the framing layer, before admission).
    pub oversized_frames: u64,
    /// Requests force-cancelled by the watchdog because they overran
    /// `deadline × watchdog_factor`. Their clients still get a response
    /// (flagged `cancelled`), so they count toward `delivered` too.
    pub watchdog_fired: u64,
}

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Self::Tcp(s) => Self::Tcp(s.try_clone()?),
            Self::Unix(s) => Self::Unix(s.try_clone()?),
        })
    }

    fn set_timeouts(&self, read: Duration, write: Duration) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => {
                s.set_read_timeout(Some(read))?;
                s.set_write_timeout(Some(write))
            }
            Self::Unix(s) => {
                s.set_read_timeout(Some(read))?;
                s.set_write_timeout(Some(write))
            }
        }
    }

    fn shutdown_both(&self) {
        let _ = match self {
            Self::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Self::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.read(buf),
            Self::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.write(buf),
            Self::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => s.flush(),
            Self::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Self::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Self::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Self::Tcp(l) => l.set_nonblocking(nb),
            Self::Unix(l) => l.set_nonblocking(nb),
        }
    }
}

/// An admitted request waiting for its compute result, held by the
/// connection's writer thread.
enum Pending {
    F64(JobHandle<JobResult<f64>>),
    F32(JobHandle<JobResult<f32>>),
    Solve(JobHandle<SolveJobResult>),
}

impl Pending {
    fn job_id(&self) -> u64 {
        match self {
            Self::F64(h) => h.id(),
            Self::F32(h) => h.id(),
            Self::Solve(h) => h.id(),
        }
    }

    fn is_done(&self) -> bool {
        match self {
            Self::F64(h) => h.is_done(),
            Self::F32(h) => h.is_done(),
            Self::Solve(h) => h.is_done(),
        }
    }

    /// Block for the result and encode the response frame for `wire_id`.
    /// A result carrying a typed [`FactorError`](crate::factor::FactorError)
    /// becomes a `FAILED` frame instead of a factor/solve response; a
    /// plain cancellation (deadline, drain ET) stays a normal response
    /// flagged `cancelled`. Either way the request counts as delivered.
    fn finish(self, wire_id: u64) -> Vec<u8> {
        match self {
            Self::F64(h) => {
                let r = h.wait();
                match &r.error {
                    Some(e) => proto::encode_failed(wire_id, &proto::Failure::from_error(e)),
                    None => proto::encode_factor_resp(wire_id, &factor_resp_f64(r)),
                }
            }
            Self::F32(h) => {
                let r = h.wait();
                match &r.error {
                    Some(e) => proto::encode_failed(wire_id, &proto::Failure::from_error(e)),
                    None => proto::encode_factor_resp(wire_id, &factor_resp_f32(r)),
                }
            }
            Self::Solve(h) => {
                let r = h.wait();
                if let Some(e) = &r.error {
                    return proto::encode_failed(wire_id, &proto::Failure::from_error(e));
                }
                proto::encode_solve_resp(
                    wire_id,
                    &proto::SolveResp {
                        prec: r.prec,
                        cancelled: r.cancelled,
                        converged: r.converged,
                        refine_iters: r.refine_iters as u32,
                        backward_error: r.backward_error,
                        secs: r.secs,
                        x: r.x,
                    },
                )
            }
        }
    }

    /// Cancel and await the job without a client to answer: the crew
    /// lease unregisters and the arena buffers return before we let go.
    fn reap(self) {
        match self {
            Self::F64(h) => {
                h.cancel();
                let _ = h.wait();
            }
            Self::F32(h) => {
                h.cancel();
                let _ = h.wait();
            }
            Self::Solve(h) => {
                h.cancel();
                let _ = h.wait();
            }
        }
    }
}

fn factor_resp_f64(r: JobResult<f64>) -> proto::FactorResp {
    proto::FactorResp {
        kind: r.kind,
        cancelled: r.cancelled,
        cols_done: r.cols_done,
        secs: r.secs,
        ipiv: r.ipiv.iter().map(|&p| p as u32).collect(),
        tau: proto::WireVec::F64(r.tau),
        a: proto::WireMat::F64(r.a),
    }
}

fn factor_resp_f32(r: JobResult<f32>) -> proto::FactorResp {
    proto::FactorResp {
        kind: r.kind,
        cancelled: r.cancelled,
        cols_done: r.cols_done,
        secs: r.secs,
        ipiv: r.ipiv.iter().map(|&p| p as u32).collect(),
        tau: proto::WireVec::F32(r.tau),
        a: proto::WireMat::F32(r.a),
    }
}

/// Reader → writer hand-off. The channel is bounded: when the writer
/// falls behind (slow client, busy compute), `send` blocks the reader,
/// which stops draining the socket — backpressure all the way to the
/// client's `write`.
enum Outgoing {
    /// A fully encoded session/reject frame, written as-is.
    Frame(Vec<u8>),
    /// An admitted request: written when its job completes.
    Job { wire_id: u64, pending: Pending },
}

struct NetShared {
    server: LuServer,
    admission: AdmissionCtl,
    cfg: NetConfig,
    /// Tells connection threads to wind down (drain/shutdown). Readers
    /// still finish a frame already on the wire (to answer it with a
    /// `Draining` reject) — until `hard_stop` flips.
    stop_conns: AtomicBool,
    /// Final phase of a drain: readers abandon even partial frames at
    /// their next read tick. Without this, a client that sends half a
    /// header and then stalls would pin its reader thread — and the
    /// drain join — forever.
    hard_stop: AtomicBool,
    /// Outstanding cancel handles by compute job id, so a drain
    /// deadline can ET work whose typed handle the writer already owns,
    /// and the watchdog can force-cancel requests stuck past
    /// `deadline × watchdog_factor`.
    cancels: Mutex<HashMap<u64, WatchEntry>>,
    conns_accepted: AtomicU64,
    delivered: AtomicU64,
    reaped: AtomicU64,
    malformed: AtomicU64,
    oversized: AtomicU64,
    watchdog_fired: AtomicU64,
}

/// One outstanding request as the watchdog sees it.
struct WatchEntry {
    tok: CancelToken,
    /// When the request was admitted and submitted.
    armed_at: Instant,
    /// The client-requested deadline; `None` exempts the request from
    /// the watchdog (nothing bounds a deadline-less run).
    deadline: Option<Duration>,
    /// Set once the watchdog cancelled this entry, so a slow request is
    /// counted (and cancelled) once, not once per poll tick.
    fired: bool,
}

/// The network daemon (module docs above). Bind with
/// [`ServeDaemon::bind`]; stop with [`ServeDaemon::shutdown`] (also runs
/// on drop).
pub struct ServeDaemon {
    shared: Arc<NetShared>,
    stop_accept: Arc<AtomicBool>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    local: BindAddr,
    unix_path: Option<PathBuf>,
    drained: AtomicBool,
}

impl ServeDaemon {
    /// Bind `addr` and start serving. A stale Unix socket file at the
    /// path is removed first (the common crashed-daemon leftover).
    pub fn bind(addr: &BindAddr, cfg: NetConfig) -> std::io::Result<Self> {
        let (listener, local, unix_path) = match addr {
            BindAddr::Tcp(a) => {
                let l = TcpListener::bind(a.as_str())?;
                let local = BindAddr::Tcp(l.local_addr()?.to_string());
                (Listener::Tcp(l), local, None)
            }
            BindAddr::Unix(p) => {
                if p.exists() {
                    std::fs::remove_file(p)?;
                }
                let l = UnixListener::bind(p)?;
                (Listener::Unix(l), BindAddr::Unix(p.clone()), Some(p.clone()))
            }
        };
        listener.set_nonblocking(true)?;
        let shared = Arc::new(NetShared {
            server: LuServer::new(cfg.serve),
            admission: AdmissionCtl::new(cfg.admission),
            cfg,
            stop_conns: AtomicBool::new(false),
            hard_stop: AtomicBool::new(false),
            cancels: Mutex::new(HashMap::new()),
            conns_accepted: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            reaped: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
            oversized: AtomicU64::new(0),
            watchdog_fired: AtomicU64::new(0),
        });
        let stop_accept = Arc::new(AtomicBool::new(false));
        let conn_threads = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop_accept);
            let threads = Arc::clone(&conn_threads);
            std::thread::Builder::new()
                .name("mlu-accept".into())
                .spawn(move || acceptor_loop(listener, shared, stop, threads))?
        };
        Ok(Self {
            shared,
            stop_accept,
            acceptor: Mutex::new(Some(acceptor)),
            conn_threads,
            local,
            unix_path,
            drained: AtomicBool::new(false),
        })
    }

    /// The bound address — with the real port for `tcp:host:0` binds.
    pub fn local_addr(&self) -> BindAddr {
        self.local.clone()
    }

    /// Counter snapshot (see [`DaemonStats`] for the drain invariant).
    pub fn stats(&self) -> DaemonStats {
        DaemonStats {
            conns_accepted: self.shared.conns_accepted.load(Ordering::Relaxed),
            admission: self.shared.admission.stats(),
            delivered: self.shared.delivered.load(Ordering::Relaxed),
            reaped: self.shared.reaped.load(Ordering::Relaxed),
            malformed: self.shared.malformed.load(Ordering::Relaxed),
            oversized_frames: self.shared.oversized.load(Ordering::Relaxed),
            watchdog_fired: self.shared.watchdog_fired.load(Ordering::Relaxed),
        }
    }

    /// The compute layer's in-flight registry (tests, introspection).
    pub fn registry(&self) -> &super::CrewRegistry {
        self.shared.server.registry()
    }

    /// The compute layer's packing-arena statistics (leak checks:
    /// `free_buffers as u64 == allocations` after a drain).
    pub fn arena_stats(&self) -> crate::blis::ArenaStats {
        self.shared.server.arena_stats()
    }

    /// Connection threads currently tracked for the drain-time join.
    /// The acceptor sweeps finished ones on every poll, so on an idle
    /// daemon this decays to the live-connection thread count rather
    /// than growing with every connection ever accepted (tests,
    /// introspection).
    pub fn tracked_conn_threads(&self) -> usize {
        self.conn_threads
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .len()
    }

    /// Graceful drain (DESIGN.md §14.6): stop accepting connections,
    /// refuse new requests with `Draining`, let admitted work finish —
    /// until `grace` expires, after which outstanding jobs are
    /// ET-cancelled (their clients still get responses, flagged
    /// `cancelled`) — then wait for every response to flush and every
    /// connection thread to exit. Completion is bounded: once the
    /// ledger settles (or the grace deadline passes), readers parked
    /// mid-frame on stalled clients are forced out at their next read
    /// tick, so a half-sent header cannot hold the drain open.
    /// Idempotent.
    pub fn drain(&self, grace: Duration) {
        if self.drained.swap(true, Ordering::AcqRel) {
            return;
        }
        let deadline = Instant::now() + grace;
        self.stop_accept.store(true, Ordering::Release);
        self.shared.admission.start_drain();
        self.shared.stop_conns.store(true, Ordering::Release);
        let mut cancelled = false;
        while !self.shared.admission.is_drained() {
            if !cancelled && Instant::now() >= deadline {
                // Grace expired: ET everything still outstanding. The
                // writers deliver the cancelled results normally. Also
                // stop waiting on partial frames — a stalled mid-frame
                // client holds no admission slot and gets no further
                // patience past the deadline.
                self.shared.hard_stop.store(true, Ordering::Release);
                for entry in self
                    .shared
                    .cancels
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .values()
                {
                    entry.tok.cancel();
                }
                cancelled = true;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // Ledger settled: every admitted request is answered. Readers
        // may still sit mid-frame on connections that hold no admission
        // slot; force them out so the joins below finish within one
        // read-timeout tick instead of at the client's leisure.
        self.shared.hard_stop.store(true, Ordering::Release);
        if let Some(h) = self
            .acceptor
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
        loop {
            let mut threads = self.conn_threads.lock().unwrap_or_else(|e| e.into_inner());
            let Some(h) = threads.pop() else { break };
            drop(threads);
            let _ = h.join();
        }
        if let Some(p) = &self.unix_path {
            let _ = std::fs::remove_file(p);
        }
    }

    /// Drain (default 5 s grace if [`drain`](Self::drain) was not
    /// already called) and stop the compute pool. Runs on drop.
    pub fn shutdown(&self) {
        self.drain(Duration::from_secs(5));
        self.shared.server.shutdown();
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn acceptor_loop(
    listener: Listener,
    shared: Arc<NetShared>,
    stop: Arc<AtomicBool>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let mut next_client: u64 = 1;
    while !stop.load(Ordering::Acquire) {
        // Join threads of connections that already ended, so a
        // long-running daemon does not keep one handle per connection
        // ever accepted (drain still joins the live stragglers).
        reap_finished(&threads);
        // Watchdog tick (DESIGN.md §15): force-cancel deadline-carrying
        // requests stuck past `deadline × watchdog_factor`.
        watchdog_sweep(&shared);
        match listener.accept() {
            Ok(stream) => {
                let client = next_client;
                next_client += 1;
                shared.conns_accepted.fetch_add(1, Ordering::Relaxed);
                match spawn_connection(stream, client, &shared) {
                    Ok(pair) => threads
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .extend(pair),
                    Err(e) => eprintln!("serve: connection {client} setup failed: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                eprintln!("serve: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One watchdog pass over the outstanding-request table: cancel every
/// deadline-carrying request that has overrun `deadline ×
/// watchdog_factor` (floored at `watchdog_min_ms`). The leader observes
/// the cancel at its next checkpoint — or, if it was wedged in an
/// injected stall, as soon as the stall ends — and the client still
/// gets its response, flagged `cancelled`. Requests without a deadline
/// are exempt: nothing bounds how long they may legitimately run.
fn watchdog_sweep(shared: &NetShared) {
    let factor = shared.cfg.watchdog_factor;
    if factor == 0 {
        return;
    }
    let min = Duration::from_millis(shared.cfg.watchdog_min_ms);
    let mut cancels = shared.cancels.lock().unwrap_or_else(|e| e.into_inner());
    for (job_id, entry) in cancels.iter_mut() {
        let Some(d) = entry.deadline else { continue };
        if entry.fired {
            continue;
        }
        let limit = std::cmp::max(d * factor, min);
        if entry.armed_at.elapsed() > limit {
            entry.tok.cancel();
            entry.fired = true;
            shared.watchdog_fired.fetch_add(1, Ordering::Relaxed);
            // Environmental capture record: the daemon force-cancelled
            // this job (trigger code 2 = watchdog; DESIGN.md §16.2).
            crate::replay::capture::record(
                crate::replay::capture::DecisionKind::EtTrigger,
                *job_id,
                0,
                2,
            );
        }
    }
}

/// Join every connection thread that has already exited, leaving live
/// ones tracked for the drain-time join.
fn reap_finished(threads: &Mutex<Vec<JoinHandle<()>>>) {
    let mut done = Vec::new();
    {
        let mut t = threads.lock().unwrap_or_else(|e| e.into_inner());
        let mut i = 0;
        while i < t.len() {
            if t[i].is_finished() {
                done.push(t.swap_remove(i));
            } else {
                i += 1;
            }
        }
    }
    for h in done {
        let _ = h.join();
    }
}

fn spawn_connection(
    stream: Stream,
    client: u64,
    shared: &Arc<NetShared>,
) -> std::io::Result<Vec<JoinHandle<()>>> {
    stream.set_timeouts(
        Duration::from_millis(shared.cfg.read_timeout_ms),
        Duration::from_secs(10),
    )?;
    let write_half = stream.try_clone()?;
    // Channel bound: the client's fairness quota plus slack for
    // handshake/reject frames. A reader blocked here is the designed
    // backpressure path.
    let bound = shared.cfg.admission.max_client_inflight + 8;
    let (tx, rx) = mpsc::sync_channel::<Outgoing>(bound);
    let dead = Arc::new(AtomicBool::new(false));
    let reader = {
        let shared = Arc::clone(shared);
        let dead = Arc::clone(&dead);
        std::thread::Builder::new()
            .name(format!("mlu-read-{client}"))
            .spawn(move || reader_loop(stream, client, shared, tx, dead))?
    };
    let writer = {
        let shared = Arc::clone(shared);
        let dead = Arc::clone(&dead);
        std::thread::Builder::new()
            .name(format!("mlu-write-{client}"))
            .spawn(move || writer_loop(write_half, client, shared, rx, dead))?
    };
    Ok(vec![reader, writer])
}

/// Send to the writer, blocking while the channel is full (the
/// backpressure path) but giving up when the connection dies. On
/// failure the message comes back so the caller can settle it — an
/// admitted `Job` must never be silently dropped (its admission slot
/// and crew lease would leak, wedging a later drain).
fn send_outgoing(
    tx: &SyncSender<Outgoing>,
    dead: &AtomicBool,
    mut msg: Outgoing,
) -> Result<(), Outgoing> {
    loop {
        if dead.load(Ordering::Acquire) {
            return Err(msg);
        }
        match tx.try_send(msg) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(m)) => {
                msg = m;
                std::thread::sleep(Duration::from_micros(200));
            }
            Err(TrySendError::Disconnected(m)) => return Err(m),
        }
    }
}

/// Send a frame, discarding it if the connection is gone (rejects and
/// handshake frames carry no daemon-side bookkeeping). Returns whether
/// the connection is still usable.
fn send_frame(tx: &SyncSender<Outgoing>, dead: &AtomicBool, bytes: Vec<u8>) -> bool {
    send_outgoing(tx, dead, Outgoing::Frame(bytes)).is_ok()
}

/// Hand an admitted job to the writer; if the connection is gone, reap
/// it here (cancel + await, release the admission slot, drop the cancel
/// token) so the accounting invariant survives. Returns whether the
/// connection is still usable.
fn send_job(
    shared: &NetShared,
    client: u64,
    tx: &SyncSender<Outgoing>,
    dead: &AtomicBool,
    wire_id: u64,
    pending: Pending,
) -> bool {
    match send_outgoing(tx, dead, Outgoing::Job { wire_id, pending }) {
        Ok(()) => true,
        Err(Outgoing::Job { pending, .. }) => {
            let job_id = pending.job_id();
            pending.reap();
            shared.reaped.fetch_add(1, Ordering::Relaxed);
            shared
                .cancels
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .remove(&job_id);
            shared.admission.release(client);
            false
        }
        Err(Outgoing::Frame(_)) => unreachable!("job send returned a frame"),
    }
}

fn reader_loop(
    mut stream: Stream,
    client: u64,
    shared: Arc<NetShared>,
    tx: SyncSender<Outgoing>,
    dead: Arc<AtomicBool>,
) {
    let max_payload = shared.cfg.max_frame;
    let stop = |idle: bool| -> bool {
        // Keep reading while the connection is alive; during a drain,
        // stay up only to finish a frame already on the wire — and not
        // even that once the drain's hard-stop phase begins (a stalled
        // partial frame must not pin this thread forever).
        !(dead.load(Ordering::Acquire)
            || shared.hard_stop.load(Ordering::Acquire)
            || (shared.stop_conns.load(Ordering::Acquire) && idle))
    };
    // Handshake: the first frame must be HELLO with a version range
    // covering ours.
    match proto::read_frame(&mut stream, max_payload, &mut |idle| stop(idle)) {
        ReadEvent::Frame(f) if f.ty == proto::T_HELLO => {
            match proto::decode_hello(&f.payload) {
                Ok((lo, hi)) if lo <= proto::VERSION && proto::VERSION <= hi => {
                    if !send_frame(&tx, &dead, proto::encode_hello_ack(proto::VERSION)) {
                        return;
                    }
                }
                Ok((lo, hi)) => {
                    let reason = format!("server speaks v{} only, client offered v{lo}..v{hi}", proto::VERSION);
                    let _ = send_frame(
                        &tx,
                        &dead,
                        proto::encode_reject(0, RejectCode::Unsupported, &reason),
                    );
                    return;
                }
                Err(e) => {
                    shared.malformed.fetch_add(1, Ordering::Relaxed);
                    let _ = send_frame(
                        &tx,
                        &dead,
                        proto::encode_reject(0, RejectCode::Malformed, &e.0),
                    );
                    return;
                }
            }
        }
        ReadEvent::Frame(_) => {
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            let _ = send_frame(
                &tx,
                &dead,
                proto::encode_reject(0, RejectCode::Malformed, "expected HELLO"),
            );
            return;
        }
        ReadEvent::Corrupt(e) => {
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            let _ = send_frame(
                &tx,
                &dead,
                proto::encode_reject(0, RejectCode::Malformed, &e.0),
            );
            return;
        }
        ReadEvent::Eof | ReadEvent::Closed | ReadEvent::Oversized(..) => return,
    }
    loop {
        match proto::read_frame(&mut stream, max_payload, &mut |idle| stop(idle)) {
            ReadEvent::Frame(f) => match f.ty {
                proto::T_FACTOR => {
                    if !handle_factor(&shared, client, &tx, &dead, f.id, &f.payload) {
                        break;
                    }
                }
                proto::T_SOLVE => {
                    if !handle_solve(&shared, client, &tx, &dead, f.id, &f.payload) {
                        break;
                    }
                }
                proto::T_GOODBYE => break,
                other => {
                    shared.malformed.fetch_add(1, Ordering::Relaxed);
                    let reason = format!("unexpected frame type 0x{other:02x}");
                    if !send_frame(
                        &tx,
                        &dead,
                        proto::encode_reject(f.id, RejectCode::Malformed, &reason),
                    ) {
                        break;
                    }
                }
            },
            ReadEvent::Oversized(id, len) => {
                shared.oversized.fetch_add(1, Ordering::Relaxed);
                let reason = format!("frame payload {len} B over the {max_payload} B limit");
                if !send_frame(
                    &tx,
                    &dead,
                    proto::encode_reject(id, RejectCode::TooLarge, &reason),
                ) {
                    break;
                }
            }
            ReadEvent::Corrupt(e) => {
                // Framing can't be trusted any more: best-effort reject,
                // then close.
                shared.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = send_frame(
                    &tx,
                    &dead,
                    proto::encode_reject(0, RejectCode::Malformed, &e.0),
                );
                break;
            }
            ReadEvent::Eof | ReadEvent::Closed => break,
        }
    }
    // Dropping `tx` lets the writer finish its queue and exit.
}

/// Decode, admit, and submit one factor request. Returns `false` when
/// the connection is gone and the reader should stop.
fn handle_factor(
    shared: &Arc<NetShared>,
    client: u64,
    tx: &SyncSender<Outgoing>,
    dead: &AtomicBool,
    wire_id: u64,
    payload: &[u8],
) -> bool {
    let req = match proto::decode_factor_req(payload) {
        Ok(r) => r,
        Err(e) => {
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            record_admission(wire_id, client, RejectCode::Malformed.code(), (0, 0));
            return send_frame(tx, dead, proto::encode_reject(wire_id, RejectCode::Malformed, &e.0));
        }
    };
    let dims = (req.a.rows(), req.a.cols());
    if let Err(code) = shared.admission.try_admit(client, dims) {
        record_admission(wire_id, client, code.code(), dims);
        let reason = admit_reason(code, shared, dims);
        return send_frame(tx, dead, proto::encode_reject(wire_id, code, &reason));
    }
    record_admission(wire_id, client, 0, dims);
    // Admission slot held from here: the writer releases it after the
    // response flushes (or the reap path does).
    let deadline = (req.deadline_ms > 0).then(|| Duration::from_millis(req.deadline_ms as u64));
    let pending = match req.a {
        proto::WireMat::F64(a) => {
            let mut r = LuRequest::new(a)
                .with_kind(req.kind)
                .with_priority(req.priority)
                .with_client(client);
            if let Some(d) = deadline {
                r = r.with_deadline(d);
            }
            if req.bo > 0 && req.bi > 0 {
                r = r.with_blocks(req.bo as usize, req.bi as usize);
            }
            let h = shared.server.submit(r);
            register_cancel(shared, h.id(), h.cancel_token(), deadline);
            Pending::F64(h)
        }
        proto::WireMat::F32(a) => {
            let mut r = LuRequest::new(a)
                .with_kind(req.kind)
                .with_priority(req.priority)
                .with_client(client);
            if let Some(d) = deadline {
                r = r.with_deadline(d);
            }
            if req.bo > 0 && req.bi > 0 {
                r = r.with_blocks(req.bo as usize, req.bi as usize);
            }
            let h = shared.server.submit(r);
            register_cancel(shared, h.id(), h.cancel_token(), deadline);
            Pending::F32(h)
        }
    };
    send_job(shared, client, tx, dead, wire_id, pending)
}

/// Decode, admit, and submit one solve request (same contract as
/// [`handle_factor`]).
fn handle_solve(
    shared: &Arc<NetShared>,
    client: u64,
    tx: &SyncSender<Outgoing>,
    dead: &AtomicBool,
    wire_id: u64,
    payload: &[u8],
) -> bool {
    let req = match proto::decode_solve_req(payload) {
        Ok(r) => r,
        Err(e) => {
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            record_admission(wire_id, client, RejectCode::Malformed.code(), (0, 0));
            return send_frame(tx, dead, proto::encode_reject(wire_id, RejectCode::Malformed, &e.0));
        }
    };
    let dims = (req.a.rows(), req.a.cols());
    if let Err(code) = shared.admission.try_admit(client, dims) {
        record_admission(wire_id, client, code.code(), dims);
        let reason = admit_reason(code, shared, dims);
        return send_frame(tx, dead, proto::encode_reject(wire_id, code, &reason));
    }
    record_admission(wire_id, client, 0, dims);
    let deadline = (req.deadline_ms > 0).then(|| Duration::from_millis(req.deadline_ms as u64));
    let mut r = SolveRequest::new(req.a, req.b)
        .with_prec(req.prec)
        .with_priority(req.priority)
        .with_client(client);
    if let Some(d) = deadline {
        r = r.with_deadline(d);
    }
    if req.bo > 0 && req.bi > 0 {
        r.bo = Some(req.bo as usize);
        r.bi = Some(req.bi as usize);
    }
    let h = shared.server.submit_solve(r);
    register_cancel(shared, h.id(), h.cancel_token(), deadline);
    send_job(shared, client, tx, dead, wire_id, Pending::Solve(h))
}

/// Capture one admission verdict (DESIGN.md §16.2) — environmental:
/// `req` is the *wire* id (the daemon decides before a server id
/// exists), `a` the connection id, `b` packs `verdict | m << 8 |
/// n << 32` (verdict 0 = admitted, else the [`RejectCode`] byte; dims
/// saturate at 24 bits). No-op unless a capture is armed.
fn record_admission(wire_id: u64, client: u64, verdict: u8, dims: (usize, usize)) {
    use crate::replay::capture::{self, DecisionKind};
    if !capture::active() {
        return;
    }
    let m = (dims.0 as u64).min(0xff_ffff);
    let n = (dims.1 as u64).min(0xff_ffff);
    capture::record(
        DecisionKind::Admission,
        wire_id,
        client,
        u64::from(verdict) | (m << 8) | (n << 32),
    );
}

fn register_cancel(shared: &NetShared, job_id: u64, tok: CancelToken, deadline: Option<Duration>) {
    shared.cancels.lock().unwrap_or_else(|e| e.into_inner()).insert(
        job_id,
        WatchEntry {
            tok,
            armed_at: Instant::now(),
            deadline,
            fired: false,
        },
    );
}

fn admit_reason(code: RejectCode, shared: &NetShared, dims: (usize, usize)) -> String {
    let cfg = shared.admission.cfg();
    match code {
        RejectCode::Overloaded => format!(
            "pending queue full ({} global / {} per client)",
            cfg.max_pending, cfg.max_client_inflight
        ),
        RejectCode::TooLarge => format!(
            "matrix {}x{} over the {} dimension cap",
            dims.0, dims.1, cfg.max_dim
        ),
        RejectCode::Draining => "daemon is draining".into(),
        other => other.name().into(),
    }
}

fn writer_loop(
    mut stream: Stream,
    client: u64,
    shared: Arc<NetShared>,
    rx: Receiver<Outgoing>,
    dead: Arc<AtomicBool>,
) {
    let mut pendings: VecDeque<(u64, Pending)> = VecDeque::new();
    let mut open = true;
    let mut write = |stream: &mut Stream, bytes: &[u8], dead: &AtomicBool| -> bool {
        if dead.load(Ordering::Acquire) {
            return false;
        }
        if stream.write_all(bytes).and_then(|_| stream.flush()).is_err() {
            // Client gone (or wedged past the write timeout): stop the
            // reader too and reap everything still outstanding.
            dead.store(true, Ordering::Release);
            stream.shutdown_both();
            return false;
        }
        true
    };
    loop {
        // Pull whatever the reader queued.
        loop {
            match rx.try_recv() {
                Ok(Outgoing::Frame(b)) => {
                    write(&mut stream, &b, &dead);
                }
                Ok(Outgoing::Job { wire_id, pending }) => pendings.push_back((wire_id, pending)),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // Deliver completed jobs in completion order.
        let mut i = 0;
        while i < pendings.len() {
            if !(dead.load(Ordering::Acquire) || pendings[i].1.is_done()) {
                i += 1;
                continue;
            }
            let Some((wire_id, pending)) = pendings.remove(i) else {
                break;
            };
            let job_id = pending.job_id();
            if dead.load(Ordering::Acquire) {
                pending.reap();
                shared.reaped.fetch_add(1, Ordering::Relaxed);
            } else {
                let frame = pending.finish(wire_id);
                if write(&mut stream, &frame, &dead) {
                    shared.delivered.fetch_add(1, Ordering::Relaxed);
                } else {
                    // The result is computed but unsendable; it
                    // counts as reaped, not delivered.
                    shared.reaped.fetch_add(1, Ordering::Relaxed);
                }
            }
            shared
                .cancels
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .remove(&job_id);
            shared.admission.release(client);
        }
        if !open && pendings.is_empty() {
            break;
        }
        // Idle: block briefly on the channel so new work wakes us, and
        // completion polling stays at a 200 µs cadence.
        match rx.recv_timeout(Duration::from_micros(200)) {
            Ok(Outgoing::Frame(b)) => {
                write(&mut stream, &b, &dead);
            }
            Ok(Outgoing::Job { wire_id, pending }) => pendings.push_back((wire_id, pending)),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => open = false,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn bind_addr_parses_all_forms() {
        assert_eq!(
            BindAddr::parse("unix:/tmp/x.sock").unwrap(),
            BindAddr::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert_eq!(
            BindAddr::parse("tcp:127.0.0.1:7070").unwrap(),
            BindAddr::Tcp("127.0.0.1:7070".into())
        );
        assert_eq!(
            BindAddr::parse("127.0.0.1:0").unwrap(),
            BindAddr::Tcp("127.0.0.1:0".into())
        );
        assert!(BindAddr::parse("unix:").is_err());
        assert!(BindAddr::parse("nonsense").is_err());
        assert_eq!(
            BindAddr::parse("unix:/a/b").unwrap().to_string(),
            "unix:/a/b"
        );
    }

    #[test]
    fn daemon_binds_drains_and_reports_consistent_stats() {
        let cfg = NetConfig {
            serve: ServeConfig {
                workers: 1,
                ..Default::default()
            },
            ..Default::default()
        };
        let daemon =
            ServeDaemon::bind(&BindAddr::Tcp("127.0.0.1:0".into()), cfg).expect("bind");
        let BindAddr::Tcp(addr) = daemon.local_addr() else {
            panic!("expected tcp")
        };
        assert!(addr.ends_with(|c: char| c.is_ascii_digit()));
        daemon.drain(Duration::from_millis(100));
        daemon.shutdown();
        let s = daemon.stats();
        assert_eq!(s.conns_accepted, 0);
        assert_eq!(s.admission.admitted, s.delivered + s.reaped);
    }
}
