//! [`ServeClient`] — the library-side counterpart of the
//! [`net`](super::net) daemon: connects over TCP or Unix socket, runs
//! the version handshake, and speaks the [`proto`](super::proto) frames.
//!
//! The client is deliberately synchronous and pipelining-friendly:
//! [`submit_factor`](ServeClient::submit_factor) /
//! [`submit_solve`](ServeClient::submit_solve) write a request frame and
//! return its id immediately; [`recv`](ServeClient::recv) blocks for the
//! next server event (response, typed rejection, or typed
//! [`Failed`](WireEvent::Failed) report), which may arrive in any
//! completion order. `mlu sclient` and the `bench_serve_net` soak
//! harness drive hundreds of these concurrently from plain threads.

use super::net::BindAddr;
use super::proto::{self, ReadEvent, Reject};
use std::cell::Cell;
use std::io::Write;
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::time::{Duration, Instant};

enum ClientStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl std::io::Read for ClientStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.read(buf),
            Self::Unix(s) => s.read(buf),
        }
    }
}

impl Write for ClientStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.write(buf),
            Self::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => s.flush(),
            Self::Unix(s) => s.flush(),
        }
    }
}

/// One event read back from the daemon; the `id` fields echo the id the
/// matching `submit_*` call returned.
#[derive(Debug)]
pub enum WireEvent {
    /// A factorization completed (possibly ET-cancelled — check
    /// [`proto::FactorResp::cancelled`]).
    Factor {
        /// The id assigned at submission.
        id: u64,
        /// The decoded response.
        resp: proto::FactorResp,
    },
    /// A solve completed.
    Solve {
        /// The id assigned at submission.
        id: u64,
        /// The decoded response.
        resp: proto::SolveResp,
    },
    /// The daemon refused a request (or, with `id == 0`, the session).
    Rejected {
        /// The id of the refused request; 0 for session-level rejects.
        id: u64,
        /// Typed code and operator-facing reason.
        reject: Reject,
    },
    /// An *admitted* request ran but its computation failed — a typed
    /// numerical error (singular input, non-finite data, not positive
    /// definite) or an internal fault (a panicked leader). Distinct
    /// from [`WireEvent::Rejected`], which refuses work before it runs;
    /// only the `Internal` code is worth retrying.
    Failed {
        /// The id assigned at submission.
        id: u64,
        /// Typed failure code, detail word, and human-readable reason.
        failure: proto::Failure,
    },
}

/// A connected protocol session (module docs above).
pub struct ServeClient {
    stream: ClientStream,
    next_id: u64,
    /// Deadline budget for one `recv` call; `None` blocks indefinitely.
    read_timeout: Cell<Option<Duration>>,
}

fn io_err(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
}

impl ServeClient {
    /// Connect to `addr` and complete the HELLO/HELLO_ACK handshake.
    /// Fails with `InvalidData` if the server rejects our version.
    pub fn connect(addr: &BindAddr) -> std::io::Result<Self> {
        let stream = match addr {
            BindAddr::Tcp(a) => ClientStream::Tcp(TcpStream::connect(a.as_str())?),
            BindAddr::Unix(p) => ClientStream::Unix(UnixStream::connect(p)?),
        };
        let mut c = Self {
            stream,
            next_id: 1,
            read_timeout: Cell::new(None),
        };
        c.stream.write_all(&proto::encode_hello(proto::VERSION, proto::VERSION))?;
        c.stream.flush()?;
        match c.read_event()? {
            (f, _) if f == proto::T_HELLO_ACK => Ok(c),
            (_, Some(WireEvent::Rejected { reject, .. })) => Err(io_err(format!(
                "server rejected session: {} ({})",
                reject.code.name(),
                reject.reason
            ))),
            _ => Err(io_err("expected HELLO_ACK")),
        }
    }

    /// Write a factorization request frame; returns its id immediately
    /// (pipelined — pair with a later [`recv`](Self::recv)).
    pub fn submit_factor(&mut self, req: &proto::FactorReq) -> std::io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.stream.write_all(&proto::encode_factor_req(id, req))?;
        self.stream.flush()?;
        Ok(id)
    }

    /// Write a solve request frame; returns its id immediately.
    pub fn submit_solve(&mut self, req: &proto::SolveReq) -> std::io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.stream.write_all(&proto::encode_solve_req(id, req))?;
        self.stream.flush()?;
        Ok(id)
    }

    /// Block for the next server event. Responses arrive in completion
    /// order, not submission order.
    pub fn recv(&mut self) -> std::io::Result<WireEvent> {
        match self.read_event()? {
            (_, Some(ev)) => Ok(ev),
            (ty, None) => Err(io_err(format!("unexpected frame type 0x{ty:02x}"))),
        }
    }

    /// Optional per-call timeout for [`recv`](Self::recv); `None`
    /// blocks indefinitely (the default).
    ///
    /// When the deadline passes, `recv` fails with
    /// [`std::io::ErrorKind::TimedOut`]. A timeout that fires *between*
    /// frames (the common case: no event has arrived yet) leaves the
    /// session synchronized — a later `recv` simply waits again. One
    /// that fires *mid-frame* (the server stalled inside a response)
    /// abandons the partial frame, so the stream can no longer be
    /// trusted and the session should be dropped; the error message
    /// says which case occurred.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        // The socket-level timeout makes blocked reads surface as
        // `WouldBlock`/`TimedOut` ticks; the deadline check in
        // `read_event` turns those into a hard per-call budget instead
        // of silently retrying forever.
        self.read_timeout.set(timeout);
        match &self.stream {
            ClientStream::Tcp(s) => s.set_read_timeout(timeout),
            ClientStream::Unix(s) => s.set_read_timeout(timeout),
        }
    }

    /// Send GOODBYE and close the session cleanly.
    pub fn goodbye(mut self) -> std::io::Result<()> {
        self.stream.write_all(&proto::encode_goodbye())?;
        self.stream.flush()
    }

    fn read_event(&mut self) -> std::io::Result<(u8, Option<WireEvent>)> {
        let deadline = self.read_timeout.get().map(|t| Instant::now() + t);
        let mut timed_out = false;
        let mut tick = |_idle: bool| match deadline {
            Some(d) if Instant::now() >= d => {
                timed_out = true;
                false
            }
            _ => true,
        };
        match proto::read_frame(&mut self.stream, usize::MAX, &mut tick) {
            ReadEvent::Frame(f) => {
                let ev = match f.ty {
                    proto::T_FACTOR_OK => Some(WireEvent::Factor {
                        id: f.id,
                        resp: proto::decode_factor_resp(&f.payload).map_err(|e| io_err(e.0))?,
                    }),
                    proto::T_SOLVE_OK => Some(WireEvent::Solve {
                        id: f.id,
                        resp: proto::decode_solve_resp(&f.payload).map_err(|e| io_err(e.0))?,
                    }),
                    proto::T_REJECT => Some(WireEvent::Rejected {
                        id: f.id,
                        reject: proto::decode_reject(&f.payload).map_err(|e| io_err(e.0))?,
                    }),
                    proto::T_FAILED => Some(WireEvent::Failed {
                        id: f.id,
                        failure: proto::decode_failed(&f.payload).map_err(|e| io_err(e.0))?,
                    }),
                    _ => None,
                };
                Ok((f.ty, ev))
            }
            // `Closed` only arises from our deadline tick returning
            // false at a frame boundary: a clean, retryable timeout.
            ReadEvent::Closed => Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "read timed out waiting for a server event (between frames; retryable)",
            )),
            ReadEvent::Eof => Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )),
            ReadEvent::Oversized(..) => Err(io_err("oversized frame from server")),
            ReadEvent::Corrupt(e) if timed_out => Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                format!("read timed out mid-frame; the session is unsynchronized, drop it ({e})"),
            )),
            ReadEvent::Corrupt(e) => Err(io_err(e.0)),
        }
    }
}
