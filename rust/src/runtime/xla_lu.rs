//! `LU_XLA` — blocked right-looking LU whose every building block is an
//! AOT-compiled XLA executable (the "rigid vendor library" baseline,
//! DESIGN.md §2/§3).
//!
//! Two modes:
//! - [`factorize_full`] runs the single `lu_{n}x{b}` artifact (the whole
//!   L2 model, Pallas GEPP inside, as one compiled graph);
//! - [`factorize_stepped`] drives the factorization iteration by
//!   iteration from Rust (panel → laswp → trsm → gepp executables),
//!   mirroring how a coordinator would call into a vendor BLAS — and
//!   illustrating exactly why such a library is *non-malleable*: each
//!   call's thread mapping is frozen inside the compiled executable.

use super::{literal_to_matrix, literal_to_pivots, matrix_to_literal, pivots_to_literal, Runtime};
use crate::matrix::Matrix;
use anyhow::{bail, Result};

/// Run the one-shot full-factorization artifact `lu_{n}x{bo}`.
/// Returns `(LU_packed, absolute pivots)`.
pub fn factorize_full(rt: &Runtime, a: &Matrix, bo: usize) -> Result<(Matrix, Vec<usize>)> {
    let n = a.rows();
    if a.cols() != n {
        bail!("LU_XLA full artifact requires a square matrix");
    }
    let name = format!("lu_{n}x{bo}");
    if !rt.has(&name) {
        bail!(
            "no artifact {name}; re-run `make artifacts` with --configs including {n}:{bo}"
        );
    }
    let outs = rt.run(&name, &[matrix_to_literal(a)?])?;
    if outs.len() != 2 {
        bail!("{name}: expected (lu, piv), got {} outputs", outs.len());
    }
    let lu = literal_to_matrix(&outs[0], n, n)?;
    let piv = literal_to_pivots(&outs[1])?;
    Ok((lu, piv))
}

/// Drive the blocked RL factorization from Rust, one artifact call per
/// kernel (panel / laswp / trsm / gepp). Returns `(LU, pivots)`.
pub fn factorize_stepped(rt: &Runtime, a: &Matrix, bo: usize) -> Result<(Matrix, Vec<usize>)> {
    let n = a.rows();
    if a.cols() != n {
        bail!("LU_XLA requires a square matrix");
    }
    let mut work = a.clone();
    let mut ipiv: Vec<usize> = Vec::with_capacity(n);
    let mut k = 0;
    while k < n {
        let b = bo.min(n - k);
        let m_panel = n - k;
        // Panel factorization.
        let panel = submatrix(&work, k, k, m_panel, b);
        let outs = rt.run(&format!("panel_{m_panel}x{b}"), &[matrix_to_literal(&panel)?])?;
        let panel_lu = literal_to_matrix(&outs[0], m_panel, b)?;
        let piv_local = literal_to_pivots(&outs[1])?;
        copy_into(&mut work, &panel_lu, k, k);
        // Interchanges on the left+right columns via the laswp artifact
        // (exported over the concatenated non-panel columns).
        let rest = n - k - b;
        if rest + k > 0 {
            let lr = concat_lr(&work, k, b, m_panel);
            let name = format!("laswp_{m_panel}x{}x{b}", rest + k);
            let outs = rt.run(
                &name,
                &[matrix_to_literal(&lr)?, pivots_to_literal(&piv_local)],
            )?;
            let swapped = literal_to_matrix(&outs[0], m_panel, rest + k)?;
            split_lr(&mut work, &swapped, k, b);
        }
        for (i, p) in piv_local.iter().enumerate() {
            ipiv.push(k + p);
            debug_assert!(k + p >= k + i);
        }
        if rest > 0 {
            // TRSM on A12.
            let a11 = submatrix(&work, k, k, b, b);
            let a12 = submatrix(&work, k, k + b, b, rest);
            let outs = rt.run(
                &format!("trsm_{b}x{rest}"),
                &[matrix_to_literal(&a11)?, matrix_to_literal(&a12)?],
            )?;
            let a12 = literal_to_matrix(&outs[0], b, rest)?;
            copy_into(&mut work, &a12, k, k + b);
            // GEPP update of A22 (the Pallas kernel).
            let mm = n - k - b;
            let c = submatrix(&work, k + b, k + b, mm, rest);
            let a21 = submatrix(&work, k + b, k, mm, b);
            let outs = rt.run(
                &format!("gepp_{mm}x{rest}x{b}"),
                &[
                    matrix_to_literal(&c)?,
                    matrix_to_literal(&a21)?,
                    matrix_to_literal(&a12)?,
                ],
            )?;
            let c = literal_to_matrix(&outs[0], mm, rest)?;
            copy_into(&mut work, &c, k + b, k + b);
        }
        k += b;
    }
    Ok((work, ipiv))
}

/// Cross-validate the Rust BLIS LU against the XLA full-model artifact:
/// returns `(max |LU_rust − LU_xla|, pivots_equal)`.
pub fn cross_validate(rt: &Runtime, a: &Matrix, bo: usize, bi: usize) -> Result<(f64, bool)> {
    let (lu_xla, piv_xla) = factorize_full(rt, a, bo)?;
    let mut lu_rust = a.clone();
    let mut crew = crate::pool::Crew::new();
    let piv_rust = crate::lu::lu_blocked_rl(
        &mut crew,
        &crate::blis::BlisParams::default(),
        lu_rust.view_mut(),
        bo,
        bi,
    );
    let diff = lu_rust.max_abs_diff(&lu_xla);
    Ok((diff, piv_rust == piv_xla))
}

fn submatrix(a: &Matrix, i: usize, j: usize, m: usize, n: usize) -> Matrix {
    Matrix::from_fn(m, n, |r, c| a[(i + r, j + c)])
}

fn copy_into(dst: &mut Matrix, src: &Matrix, i: usize, j: usize) {
    for c in 0..src.cols() {
        for r in 0..src.rows() {
            dst[(i + r, j + c)] = src[(r, c)];
        }
    }
}

/// Columns `[0,k) ++ [k+b, n)` over rows `k..n` (the laswp artifact's
/// operand layout: right block first? No — left then right, matching
/// `model.lu_blocked`'s concatenation order `[left | right]`).
fn concat_lr(a: &Matrix, k: usize, b: usize, m_panel: usize) -> Matrix {
    let n = a.cols();
    let rest = n - k - b;
    Matrix::from_fn(m_panel, k + rest, |r, c| {
        if c < k {
            a[(k + r, c)]
        } else {
            a[(k + r, k + b + (c - k))]
        }
    })
}

fn split_lr(a: &mut Matrix, lr: &Matrix, k: usize, b: usize) {
    let n = a.cols();
    let rest = n - k - b;
    for c in 0..k {
        for r in 0..lr.rows() {
            a[(k + r, c)] = lr[(r, c)];
        }
    }
    for c in 0..rest {
        for r in 0..lr.rows() {
            a[(k + r, k + b + c)] = lr[(r, k + c)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn helpers_roundtrip() {
        let a = Matrix::from_fn(6, 6, |i, j| (i * 10 + j) as f64);
        let s = submatrix(&a, 1, 2, 3, 2);
        assert_eq!(s[(0, 0)], 12.0);
        let mut b = Matrix::zeros(6, 6);
        copy_into(&mut b, &s, 1, 2);
        assert_eq!(b[(1, 2)], 12.0);
        assert_eq!(b[(3, 3)], 33.0);
    }

    #[test]
    fn concat_split_are_inverses() {
        let a0 = Matrix::from_fn(8, 8, |i, j| (i * 8 + j) as f64);
        let (k, b) = (2usize, 3usize);
        let m_panel = 8 - k;
        let lr = concat_lr(&a0, k, b, m_panel);
        assert_eq!(lr.cols(), 8 - b);
        assert_eq!(lr.rows(), m_panel);
        // Identity roundtrip.
        let mut a = a0.clone();
        split_lr(&mut a, &lr, k, b);
        assert_eq!(a, a0);
        // Check addressing: lr col 0 = a col 0 (rows k..), lr col k = a col k+b.
        assert_eq!(lr[(0, 0)], a0[(k, 0)]);
        assert_eq!(lr[(0, k)], a0[(k, k + b)]);
    }
}
