//! Minimal in-crate stand-in for the `xla` PJRT bindings.
//!
//! The offline build environment carries no XLA/PJRT native bindings
//! (DESIGN.md §3), so this module provides the small API surface the
//! [`super`] runtime uses: fully functional, pure-Rust data-carrying
//! [`Literal`]s (the conversion helpers and their tests work unchanged)
//! plus client/executable types whose compile/execute paths return a
//! clear "PJRT unavailable" error. Swapping the real bindings back in is
//! a matter of replacing this module with the external crate; every
//! signature matches the subset of the bindings' API we call.

use std::fmt;
use std::path::Path;

/// Debug-printable error mirroring the bindings' error type (the runtime
/// formats these with `{e:?}`).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Result alias mirroring the bindings' convention.
pub type XlaResult<T> = Result<T, XlaError>;

fn err<T>(msg: impl Into<String>) -> XlaResult<T> {
    Err(XlaError(msg.into()))
}

#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F64(Vec<f64>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F64(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Host-side typed array with a shape — the only part of the bindings
/// that must actually *work* offline (matrix/pivot interchange).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Element types a stub [`Literal`] can carry (the artifacts use f64
/// data and i32 pivots).
pub trait NativeElem: Sized + Copy {
    /// Wrap a host vector as a rank-1 literal.
    fn into_literal(v: Vec<Self>) -> Literal;
    /// Extract the flattened elements (type-checked).
    fn extract(lit: &Literal) -> XlaResult<Vec<Self>>;
}

impl NativeElem for f64 {
    fn into_literal(v: Vec<Self>) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            payload: Payload::F64(v),
        }
    }

    fn extract(lit: &Literal) -> XlaResult<Vec<Self>> {
        match &lit.payload {
            Payload::F64(v) => Ok(v.clone()),
            Payload::I32(_) => err("literal holds i32, asked for f64"),
        }
    }
}

impl NativeElem for i32 {
    fn into_literal(v: Vec<Self>) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            payload: Payload::I32(v),
        }
    }

    fn extract(lit: &Literal) -> XlaResult<Vec<Self>> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F64(_) => err("literal holds f64, asked for i32"),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeElem>(v: &[T]) -> Literal {
        T::into_literal(v.to_vec())
    }

    /// Same data, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let count: i64 = dims.iter().product();
        if count != self.payload.len() as i64 {
            return err(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.payload.len()
            ));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Flattened element vector.
    pub fn to_vec<T: NativeElem>(&self) -> XlaResult<Vec<T>> {
        T::extract(self)
    }

    /// Stub literals are never tuples (tuples only come back from a real
    /// PJRT execution).
    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        err("stub literal is not a tuple (PJRT backend unavailable)")
    }

    /// Shape of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO text (held verbatim; only a real backend can compile it).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file.
    pub fn from_text_file(path: impl AsRef<Path>) -> XlaResult<Self> {
        match std::fs::read_to_string(path.as_ref()) {
            Ok(text) => Ok(Self { text }),
            Err(e) => err(format!("read {:?}: {e}", path.as_ref())),
        }
    }
}

/// A computation wrapping parsed HLO, ready to hand to a client.
pub struct XlaComputation {
    hlo_bytes: usize,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self {
            hlo_bytes: proto.text.len(),
        }
    }
}

/// Stand-in PJRT client: constructible (so artifact stores open and
/// manifests parse offline), but compilation reports the missing
/// backend.
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the (stub) CPU client.
    pub fn cpu() -> XlaResult<Self> {
        Ok(Self)
    }

    /// Compile HLO — always reports the missing backend offline.
    pub fn compile(&self, comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        err(format!(
            "PJRT backend not linked in this offline build; cannot compile {} bytes of HLO",
            comp.hlo_bytes
        ))
    }
}

/// Stand-in compiled executable (never actually constructible offline).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute — always reports the missing backend offline.
    pub fn execute<T>(&self, _inputs: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        err("PJRT backend not linked in this offline build")
    }
}

/// Stand-in device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy back to host — always reports the missing backend offline.
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        err("PJRT backend not linked in this offline build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.dims(), &[6]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_opens_but_compile_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto {
            text: "HloModule m".into(),
        };
        let comp = XlaComputation::from_proto(&proto);
        let e = client.compile(&comp).err().unwrap();
        assert!(format!("{e}").contains("PJRT backend"), "{e}");
    }

    #[test]
    fn missing_hlo_file_is_a_clean_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
