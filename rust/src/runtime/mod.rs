//! PJRT/XLA runtime — loads the AOT-compiled Pallas/JAX artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once by
//! `make artifacts`) lowers the L2 model + L1 Pallas kernel to **HLO
//! text**; this module loads the text through
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client
//! and executes it from Rust — Python is never on the request path.
//!
//! In the reproduction's terms (DESIGN.md §2), an artifact is a *rigid
//! vendor BLAS*: shape-specialized, black-box, non-malleable. The
//! [`xla_lu`] module builds the `LU_XLA` baseline from these, and the
//! integration tests cross-validate the Rust BLIS substrate against the
//! XLA numerics.

pub mod xla;
pub mod xla_lu;

use crate::matrix::Matrix;
use crate::util::json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One entry of `manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Artifact name (the manifest key).
    pub name: String,
    /// Artifact class (e.g. `lu_full`, `lu_step`).
    pub kind: String,
    /// HLO text file, relative to the store directory.
    pub file: String,
    /// Input shapes (row-major, as exported by jax).
    pub input_shapes: Vec<Vec<usize>>,
    /// Input element types (as exported by jax).
    pub input_dtypes: Vec<String>,
    /// Output names, in tuple order.
    pub outputs: Vec<String>,
}

/// Artifact store + PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactMeta>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        if doc.get("format").and_then(|v| v.as_str()) != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        let mut artifacts = HashMap::new();
        for a in doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest: no artifacts array"))?
        {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact without name"))?
                .to_string();
            let meta = ArtifactMeta {
                name: name.clone(),
                kind: a
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("artifact {name} without file"))?
                    .to_string(),
                input_shapes: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|i| {
                        i.get("shape")
                            .and_then(|v| v.as_arr())
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect()
                    })
                    .collect(),
                input_dtypes: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|i| {
                        i.get("dtype")
                            .and_then(|v| v.as_str())
                            .unwrap_or("float64")
                            .to_string()
                    })
                    .collect(),
                outputs: a
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|o| o.as_str().map(str::to_string))
                    .collect(),
            };
            artifacts.insert(name, meta);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir,
            artifacts,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Names of all loadable artifacts.
    pub fn available(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    /// Metadata for one artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    /// Does an artifact exist?
    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(e));
        }
        let meta = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name}"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact. Inputs/outputs are [`xla::Literal`]s; the
    /// exported computations return a tuple, which is flattened here.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let bufs = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Convert a column-major [`Matrix`] to a row-major f64 literal of shape
/// `[rows, cols]` (jax's layout).
pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    let rm = m.to_row_major();
    xla::Literal::vec1(&rm)
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Convert a row-major f64 literal back to a [`Matrix`].
pub fn literal_to_matrix(l: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = l
        .to_vec::<f64>()
        .map_err(|e| anyhow!("literal to_vec<f64>: {e:?}"))?;
    if v.len() != rows * cols {
        bail!("literal has {} elements, expected {rows}x{cols}", v.len());
    }
    Ok(Matrix::from_row_major(rows, cols, &v))
}

/// Convert an i32 pivot literal to `Vec<usize>`.
pub fn literal_to_pivots(l: &xla::Literal) -> Result<Vec<usize>> {
    let v = l
        .to_vec::<i32>()
        .map_err(|e| anyhow!("literal to_vec<i32>: {e:?}"))?;
    Ok(v.into_iter().map(|x| x as usize).collect())
}

/// Build an i32 literal from pivots.
pub fn pivots_to_literal(piv: &[usize]) -> xla::Literal {
    let v: Vec<i32> = piv.iter().map(|&p| p as i32).collect();
    xla::Literal::vec1(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: tests needing real artifacts live in rust/tests/ and skip
    // when artifacts/ is absent. Here: pure conversion + manifest logic.

    #[test]
    fn matrix_literal_roundtrip() {
        let m = Matrix::random(5, 7, 3);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&lit, 5, 7).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn pivot_literal_roundtrip() {
        let piv = vec![3usize, 1, 4, 1, 5];
        let lit = pivots_to_literal(&piv);
        assert_eq!(literal_to_pivots(&lit).unwrap(), piv);
    }

    #[test]
    fn literal_shape_mismatch_is_error() {
        let m = Matrix::random(2, 2, 1);
        let lit = matrix_to_literal(&m).unwrap();
        assert!(literal_to_matrix(&lit, 3, 3).is_err());
    }

    #[test]
    fn open_missing_dir_fails_with_hint() {
        let msg = match Runtime::open("/nonexistent-artifacts") {
            Ok(_) => panic!("open should fail"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn manifest_parsing_via_tempdir() {
        let dir = std::env::temp_dir().join(format!("mlu-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-text", "artifacts": [
                {"name": "x", "kind": "gepp", "file": "x.hlo.txt",
                 "inputs": [{"shape": [2, 3], "dtype": "float64"}],
                 "outputs": ["c_f64"]}]}"#,
        )
        .unwrap();
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.has("x"));
        assert_eq!(rt.meta("x").unwrap().input_shapes[0], vec![2, 3]);
        assert_eq!(rt.available(), vec!["x".to_string()]);
        assert_eq!(rt.cached(), 0);
        // Running a missing-file artifact errors cleanly.
        assert!(rt.run("x", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
