//! §scalar — the **sealed precision layer** (DESIGN.md §12).
//!
//! Everything numeric in this crate — [`crate::matrix::Mat`], the BLIS
//! substrate, the factorization drivers, the serve layer — is generic
//! over one trait, [`Scalar`], implemented for exactly `f32` and `f64`.
//! The trait is **sealed**: downstream code cannot add implementations,
//! which is what lets the kernels promise per-type properties (a
//! registered micro-kernel, a SIMD lane width, the fused-reduction
//! bitwise contract) without defensive checks at every call site.
//!
//! What an implementation provides:
//!
//! - the usual arithmetic (via the `core::ops` supertraits) plus the
//!   handful of float intrinsics the kernels need ([`Scalar::mul_add`],
//!   [`Scalar::sqrt`], [`Scalar::abs`], …);
//! - numeric metadata: [`Scalar::EPSILON`] (for tolerance-scaled
//!   residual checks), [`Scalar::SIMD_LANES`] (AVX2 width: 4 for `f64`,
//!   8 for `f32`), [`Scalar::FLOP_RATE`] (modeled throughput relative to
//!   `f64`, consumed by the serve layer's cost model);
//! - the **micro-kernel registry entry** ([`Scalar::micro_kernel`]): the
//!   type's register-blocked GEMM micro-kernel, dispatching between its
//!   AVX2+FMA implementation and the shared portable fallback. The two
//!   are bitwise identical under the fused-reduction contract
//!   (DESIGN.md §9), per type — so the repo-wide determinism invariant
//!   (§8) holds in both precisions.
//!
//! Conversions go through `f64` ([`Scalar::from_f64`] /
//! [`Scalar::to_f64`]): `f32 → f64` is exact, `f64 → f32` rounds to
//! nearest — the demotion the mixed-precision solver
//! ([`crate::solve::lu_solve_mixed`]) performs once per system.

use crate::matrix::MatMut;

mod sealed {
    /// Seal: only `f32` and `f64` may implement [`super::Scalar`].
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// The sealed scalar-type contract of the numeric core (module docs).
///
/// Implemented for `f32` and `f64` only. Future precisions (`f16`,
/// `bf16`) slot in here: implement the trait, register a micro-kernel,
/// and every layer above — matrix, BLIS, factorization drivers, serve —
/// works unchanged.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + 'static
{
    /// Canonical lowercase name, used in trace tags (`req3:lu:f32`),
    /// bench records (`"prec"` fields), and CLI flags.
    const NAME: &'static str;
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Machine epsilon of the type — the unit for tolerance-scaled
    /// residual checks (a residual `< c·n·EPSILON` is "as good as this
    /// precision gets").
    const EPSILON: Self;
    /// Elements per AVX2 (256-bit) vector: 4 for `f64`, 8 for `f32`.
    const SIMD_LANES: usize;
    /// Modeled flop throughput relative to `f64` (1.0 for `f64`, 2.0
    /// for `f32`: twice the SIMD lanes, half the memory traffic). The
    /// serve layer's cost model divides modeled seconds by this rate so
    /// mixed-precision batches share one starvation metric.
    const FLOP_RATE: f64;

    /// Round an `f64` into this type (exact for `f64`, nearest for
    /// `f32`).
    fn from_f64(v: f64) -> Self;
    /// Widen into `f64` (always exact for the sealed types).
    fn to_f64(self) -> f64;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Square root.
    fn sqrt(self) -> Self;
    /// Fused multiply-add `self * a + b` with a single rounding — the
    /// operation the micro-kernel bitwise contract is built on.
    fn mul_add(self, a: Self, b: Self) -> Self;
    /// The larger of `self` and `other` (IEEE `maxNum` semantics).
    fn max(self, other: Self) -> Self;
    /// Raw bits, widened to `u64` — for bitwise-identity assertions
    /// across kernels and crew sizes.
    fn to_bits_u64(self) -> u64;
    /// Whether the value is finite (not NaN / ±inf).
    fn is_finite(self) -> bool;

    /// The type's registered GEMM micro-kernel (DESIGN.md §12): compute
    /// `C_tile += alpha · A_panel · B_panel` over `k`-deep packed
    /// micro-panels, writing the `m_eff × n_eff` live tile at `c`'s
    /// origin. With `simd` set the caller has verified AVX2+FMA support
    /// ([`crate::blis::micro::simd_available`]) and the type's SIMD
    /// kernel runs; otherwise the shared portable fallback runs. Both
    /// produce bitwise-identical results (the §9 contract), so the flag
    /// is a pure performance choice.
    #[allow(clippy::too_many_arguments)]
    fn micro_kernel(
        simd: bool,
        k: usize,
        alpha: Self,
        a_panel: &[Self],
        b_panel: &[Self],
        c: MatMut<Self>,
        m_eff: usize,
        n_eff: usize,
    );

    /// The type's registered interleaved small-batch LU kernel
    /// (DESIGN.md §18): factor `SIMD_LANES` independent `m × n` problems
    /// laid out problem-major in `data` (`data[(j*m + i) * SIMD_LANES + l]`
    /// is element `(i, j)` of problem `l`), writing per-problem pivots to
    /// `ipiv[k * SIMD_LANES + l]`. With `simd` set the caller has verified
    /// AVX2+FMA support and the type's vector kernel runs; otherwise the
    /// portable per-lane fallback runs. Both replicate
    /// [`crate::blis::small::lu_step_col`] per lane and produce
    /// bitwise-identical results, so the flag is a pure performance
    /// choice.
    fn small_lu_kernel(simd: bool, data: &mut [Self], m: usize, n: usize, ipiv: &mut [usize]);
}

impl Scalar for f64 {
    const NAME: &'static str = "f64";
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f64::EPSILON;
    const SIMD_LANES: usize = 4;
    const FLOP_RATE: f64 = 1.0;

    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline(always)]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f64::mul_add(self, a, b)
    }
    #[inline(always)]
    fn max(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline(always)]
    fn to_bits_u64(self) -> u64 {
        self.to_bits()
    }
    #[inline(always)]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }

    #[inline]
    fn micro_kernel(
        simd: bool,
        k: usize,
        alpha: Self,
        a_panel: &[Self],
        b_panel: &[Self],
        c: MatMut<Self>,
        m_eff: usize,
        n_eff: usize,
    ) {
        #[cfg(target_arch = "x86_64")]
        if simd {
            // SAFETY: `simd` is only passed as true after
            // `micro::simd_available()` confirmed AVX2+FMA (dispatch
            // contract of `blis::micro::micro_kernel`).
            unsafe {
                crate::blis::micro::micro_kernel_avx2(
                    k, alpha, a_panel, b_panel, c, m_eff, n_eff,
                )
            };
            return;
        }
        let _ = simd;
        crate::blis::micro::micro_kernel_portable(k, alpha, a_panel, b_panel, c, m_eff, n_eff);
    }

    #[inline]
    fn small_lu_kernel(simd: bool, data: &mut [Self], m: usize, n: usize, ipiv: &mut [usize]) {
        #[cfg(target_arch = "x86_64")]
        if simd {
            // SAFETY: `simd` implies AVX2+FMA per the dispatch contract.
            unsafe { crate::blis::smallbatch::small_lu_avx2(data, m, n, ipiv) };
            return;
        }
        let _ = simd;
        crate::blis::smallbatch::small_lu_portable::<Self>(data, m, n, ipiv);
    }
}

impl Scalar for f32 {
    const NAME: &'static str = "f32";
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f32::EPSILON;
    const SIMD_LANES: usize = 8;
    const FLOP_RATE: f64 = 2.0;

    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline(always)]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f32::mul_add(self, a, b)
    }
    #[inline(always)]
    fn max(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline(always)]
    fn to_bits_u64(self) -> u64 {
        u64::from(self.to_bits())
    }
    #[inline(always)]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }

    #[inline]
    fn micro_kernel(
        simd: bool,
        k: usize,
        alpha: Self,
        a_panel: &[Self],
        b_panel: &[Self],
        c: MatMut<Self>,
        m_eff: usize,
        n_eff: usize,
    ) {
        #[cfg(target_arch = "x86_64")]
        if simd {
            // SAFETY: as in the f64 impl — `simd` implies AVX2+FMA.
            unsafe {
                crate::blis::micro::micro_kernel_avx2_f32(
                    k, alpha, a_panel, b_panel, c, m_eff, n_eff,
                )
            };
            return;
        }
        let _ = simd;
        crate::blis::micro::micro_kernel_portable(k, alpha, a_panel, b_panel, c, m_eff, n_eff);
    }

    #[inline]
    fn small_lu_kernel(simd: bool, data: &mut [Self], m: usize, n: usize, ipiv: &mut [usize]) {
        #[cfg(target_arch = "x86_64")]
        if simd {
            // SAFETY: as in the f64 impl — `simd` implies AVX2+FMA.
            unsafe { crate::blis::smallbatch::small_lu_avx2_f32(data, m, n, ipiv) };
            return;
        }
        let _ = simd;
        crate::blis::smallbatch::small_lu_portable::<Self>(data, m, n, ipiv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_are_consistent() {
        assert_eq!(f64::NAME, "f64");
        assert_eq!(f32::NAME, "f32");
        assert_eq!(<f64 as Scalar>::EPSILON, f64::EPSILON);
        assert_eq!(<f32 as Scalar>::EPSILON, f32::EPSILON);
        // Twice the lanes, twice the modeled rate.
        assert_eq!(f32::SIMD_LANES, 2 * f64::SIMD_LANES);
        assert_eq!(f32::FLOP_RATE, 2.0 * f64::FLOP_RATE);
    }

    #[test]
    fn conversions_roundtrip() {
        for v in [0.0f64, 1.5, -2.25, 1e-3] {
            // Values exactly representable in f32 survive the roundtrip.
            assert_eq!(<f32 as Scalar>::from_f64(v).to_f64(), v);
            assert_eq!(<f64 as Scalar>::from_f64(v), v);
        }
        // f64→f32 rounds: a value below f32 resolution collapses.
        let tiny = 1.0 + f64::EPSILON;
        assert_eq!(<f32 as Scalar>::from_f64(tiny), 1.0f32);
    }

    fn fused_chain<S: Scalar>(n: usize) -> S {
        let mut acc = S::ZERO;
        for i in 0..n {
            let x = S::from_f64(0.1 + i as f64);
            acc = x.mul_add(S::from_f64(0.25), acc);
        }
        acc
    }

    #[test]
    fn generic_arithmetic_matches_concrete() {
        // The generic fused chain must be the exact chain the concrete
        // types compute (this is the contract kernels rely on).
        let g64 = fused_chain::<f64>(17);
        let mut c64 = 0.0f64;
        for i in 0..17 {
            c64 = (0.1 + i as f64).mul_add(0.25, c64);
        }
        assert_eq!(g64.to_bits(), c64.to_bits());

        let g32 = fused_chain::<f32>(17);
        let mut c32 = 0.0f32;
        for i in 0..17 {
            c32 = ((0.1 + i as f64) as f32).mul_add(0.25, c32);
        }
        assert_eq!(g32.to_bits(), c32.to_bits());
    }
}
