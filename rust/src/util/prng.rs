//! A small, fast, deterministic PRNG (xoshiro256**). No external `rand`
//! crate is available offline; this generator is used for test matrices,
//! property-test case generation and workload synthesis.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seeded constructor. Any seed (including 0) is valid: the state is
    /// expanded with SplitMix64 so it is never all-zero.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Next uniformly distributed `u64`.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution, matching the paper's
    /// "random entries uniformly distributed in (0,1)" workload).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `usize` in `[0, bound)`. `bound` must be > 0.
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "Prng::below: bound must be > 0");
        // Multiply-shift bounded sampling (Lemire); slight bias is fine for
        // tests/workloads.
        let x = self.next_u64() as u128;
        ((x * bound as u128) >> 64) as usize
    }

    /// Uniform `usize` in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut p = Prng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_respects_bound_and_hits_all_values() {
        let mut p = Prng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = p.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut p = Prng::new(9);
        for _ in 0..100 {
            let v = p.range(5, 8);
            assert!((5..=8).contains(&v));
        }
        assert_eq!(p.range(4, 4), 4);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut p = Prng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
