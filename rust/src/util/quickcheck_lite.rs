//! A miniature property-testing harness (the offline registry has no
//! `proptest`; DESIGN.md §3 documents the substitution).
//!
//! Usage:
//!
//! ```
//! use malleable_lu::util::quickcheck_lite::{forall, Gen};
//!
//! forall("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     g.label(format!("a={a} b={b}"));
//!     a + b == b + a
//! });
//! ```
//!
//! On failure the harness re-runs the failing case with a fixed seed and
//! panics with the case label, so failures are reproducible (`QC_SEED`
//! environment variable overrides the base seed).

use super::prng::Prng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Prng,
    label: String,
    /// Zero-based index of the case being generated.
    pub case_index: usize,
}

impl Gen {
    fn new(seed: u64, case_index: usize) -> Self {
        Self {
            rng: Prng::new(seed),
            label: String::new(),
            case_index,
        }
    }

    /// Attach a human-readable description of the generated case; shown on
    /// failure.
    pub fn label(&mut self, s: impl Into<String>) {
        self.label = s.into();
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// Uniform f64 in `[0,1)`.
    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Biased coin.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one of the provided values.
    pub fn choose<T: Clone>(&mut self, xs: &[T]) -> T {
        self.rng.pick(xs).clone()
    }

    /// A fresh seed derived from this case (for seeding nested structures
    /// deterministically).
    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Vector of `len` values built by `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut xs: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut xs);
        xs
    }
}

fn base_seed() -> u64 {
    std::env::var("QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00_D15E_A5E5)
}

/// Run `prop` on `cases` generated cases; panic (with the case label and a
/// reproduction hint) on the first failing case.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> bool) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, i);
        let ok = prop(&mut g);
        if !ok {
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}); \
                 label: {}; rerun with QC_SEED={base}",
                if g.label.is_empty() { "<none>" } else { &g.label }
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so it can
/// report rich failure diagnostics.
pub fn forall_res(
    name: &str,
    cases: usize,
    mut prop: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, i);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}): {msg}; \
                 label: {}; rerun with QC_SEED={base}",
                if g.label.is_empty() { "<none>" } else { &g.label }
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivially true", 25, |_g| {
            count += 1;
            true
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed at case 0")]
    fn failing_property_panics_with_name() {
        forall("always false", 10, |g| {
            g.label("the case");
            false
        });
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut first: Vec<usize> = Vec::new();
        forall("collect", 5, |g| {
            first.push(g.usize_in(0, 1_000_000));
            true
        });
        let mut second: Vec<usize> = Vec::new();
        forall("collect", 5, |g| {
            second.push(g.usize_in(0, 1_000_000));
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn permutation_is_valid() {
        forall("perm valid", 20, |g| {
            let n = g.usize_in(0, 32);
            let p = g.permutation(n);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            sorted == (0..n).collect::<Vec<_>>()
        });
    }

    #[test]
    fn forall_res_reports_message() {
        let result = std::panic::catch_unwind(|| {
            forall_res("resprop", 3, |g| {
                if g.case_index == 2 {
                    Err("boom".to_string())
                } else {
                    Ok(())
                }
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("case 2"), "{msg}");
    }
}
