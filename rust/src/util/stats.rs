//! Summary statistics for the hand-rolled benchmark harness (criterion is
//! not available offline — DESIGN.md §3).

/// Summary of a sample of measurements (e.g. seconds per repetition).
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    /// Sample count.
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (midpoint of the two central samples when `n` is even).
    pub median: f64,
    /// Sample standard deviation (0 for a single sample).
    pub stddev: f64,
}

impl Stats {
    /// Compute summary statistics of a non-empty sample.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Stats::of: empty sample");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            n,
            min,
            max,
            mean,
            median,
            stddev: var.sqrt(),
        }
    }

    /// Relative spread, `(max-min)/median`; a quick noise indicator.
    pub fn spread(&self) -> f64 {
        if self.median == 0.0 {
            0.0
        } else {
            (self.max - self.min) / self.median
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} min={:.3e} med={:.3e} mean={:.3e} max={:.3e} sd={:.1e}",
            self.n, self.min, self.median, self.mean, self.max, self.stddev
        )
    }
}

/// Run `f` for `warmup` un-measured and `reps` measured repetitions and
/// return timing statistics in seconds.
pub fn bench_seconds(warmup: usize, reps: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::of(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_sample() {
        let s = Stats::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-15);
        assert!((s.stddev - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_even_sample_median_interpolates() {
        let s = Stats::of(&[1.0, 2.0, 3.0, 10.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn stats_single_sample() {
        let s = Stats::of(&[5.0]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.spread(), 0.0);
    }

    #[test]
    fn bench_runs_expected_times() {
        let mut count = 0usize;
        let s = bench_seconds(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn stats_empty_panics() {
        let _ = Stats::of(&[]);
    }
}
