//! Minimal JSON parser and serializer (objects, arrays, strings,
//! numbers, booleans, null) — enough to read `artifacts/manifest.json`
//! and to emit the machine-readable `BENCH_*.json` files. No `serde` in
//! the offline registry (DESIGN.md §3).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always carried as `f64`).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (sorted keys, so serialization is deterministic).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field lookup (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric payload truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from key/value pairs (bench-record convenience).
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Value)>) -> Value {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Serialize to a compact JSON string. Non-finite numbers become
    /// `null` (JSON has no NaN/inf).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.dump_into(&mut out);
        out
    }

    fn dump_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.is_finite() {
                    // `{}` on f64 round-trips and never emits NaN/inf here.
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.dump_into(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).dump_into(out);
                    out.push(':');
                    v.dump_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Value, String> {
    let mut p = Parser {
        b: s.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (got {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|x| x as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // UTF-8 passthrough: find the char boundary.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
 "format": "hlo-text",
 "artifacts": [
  {"name": "gepp_64x64x64", "inputs": [{"shape": [64, 64], "dtype": "float64"}], "outputs": ["c_f64"]},
  {"name": "lu_192x64", "inputs": [{"shape": [192, 192], "dtype": "float64"}], "outputs": ["lu_f64", "piv_i32"]}
 ]
}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("gepp_64x64x64"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(64));
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn nested() {
        let v = parse(r#"[[1,2],[3,[4]]]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(4.0));
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let v = Value::obj([
            ("name", Value::Str("gemm 512".into())),
            ("gflops", Value::Num(12.25)),
            ("threads", Value::Num(4.0)),
            ("shape", Value::Arr(vec![Value::Num(512.0), Value::Num(512.0)])),
            ("quick", Value::Bool(false)),
            ("note", Value::Str("line1\nline\"2\"".into())),
        ]);
        let s = v.dump();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn dump_handles_non_finite_and_empty() {
        assert_eq!(Value::Num(f64::NAN).dump(), "null");
        assert_eq!(Value::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Value::Arr(vec![]).dump(), "[]");
        assert_eq!(Value::Obj(Default::default()).dump(), "{}");
    }
}
