//! Small self-contained utilities: PRNG, statistics, timing helpers and a
//! miniature property-testing harness.
//!
//! The offline build environment has no `rand`, `criterion` or `proptest`
//! crates available, so this module provides the minimal replacements the
//! rest of the crate needs (documented as a substitution in DESIGN.md §3).

pub mod json;
pub mod prng;
pub mod quickcheck_lite;
pub mod stats;

pub use prng::Prng;
pub use stats::Stats;

use std::time::Instant;

/// Wall-clock duration of `f` in seconds, together with its result.
pub fn timed<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Flop count of an `m × n` LU factorization with partial pivoting
/// (`mn² − n³/3`; pivoting's O(n²) comparisons are not counted, matching
/// the paper's convention).
pub fn lu_flops(m: usize, n: usize) -> f64 {
    let (m, n) = (m as f64, n as f64);
    m * n * n - n * n * n / 3.0
}

/// Flop count of `C += A·B` with `A` `m×k`, `B` `k×n`.
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// Flop count of a unit-lower-triangular left solve `TRILU(A)⁻¹ B` with
/// `A` `m×m`, `B` `m×n`.
pub fn trsm_flops(m: usize, n: usize) -> f64 {
    m as f64 * m as f64 * n as f64
}

/// GFLOPS given a flop count and elapsed seconds.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    flops / secs / 1e9
}

/// Round `x` up to the next multiple of `q` (`q > 0`).
pub fn round_up(x: usize, q: usize) -> usize {
    x.div_ceil(q) * q
}

/// Split `n` items into `parts` contiguous ranges, as evenly as possible.
/// The first `n % parts` ranges get one extra item. Empty ranges are
/// returned when `parts > n`.
pub fn even_split(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    assert!(parts > 0, "even_split: parts must be > 0");
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lu_flops_square_matches_two_thirds_cubed() {
        let n = 1200usize;
        let exact = lu_flops(n, n);
        let approx = 2.0 * (n as f64).powi(3) / 3.0;
        assert!((exact - approx).abs() / approx < 1e-12);
    }

    #[test]
    fn lu_flops_front_loading_matches_paper_claims() {
        // Paper §3.1: for the RL variant, the first 25% of iterations
        // account for ~58% of the flops, the first half for 87.5%, the
        // first 75% for >98%. Work in iteration k is ~2(n-k)² per unit
        // column. Integrate flops of the leading fraction f:
        // 1 - (1-f)³.
        let frac = |f: f64| 1.0 - (1.0 - f).powi(3);
        assert!((frac(0.25) - 0.578125).abs() < 1e-9); // ≈ 58%
        assert!((frac(0.50) - 0.875).abs() < 1e-12); // 87.5%
        assert!(frac(0.75) > 0.98);
    }

    #[test]
    fn gemm_trsm_flops() {
        assert_eq!(gemm_flops(2, 3, 4) as u64, 48);
        assert_eq!(trsm_flops(3, 5) as u64, 45);
    }

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn even_split_covers_everything_contiguously() {
        for n in [0usize, 1, 5, 17, 100] {
            for parts in [1usize, 2, 3, 7, 16] {
                let ranges = even_split(n, parts);
                assert_eq!(ranges.len(), parts);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, n);
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let max = *lens.iter().max().unwrap();
                let min = *lens.iter().min().unwrap();
                assert!(max - min <= 1, "uneven split: {lens:?}");
            }
        }
    }

    #[test]
    fn timed_returns_result() {
        let (secs, v) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn gflops_handles_zero_time() {
        assert_eq!(gflops(1e9, 0.0), 0.0);
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
    }
}
