//! `mlu` — the malleable-LU coordinator CLI.
//!
//! ```text
//! mlu factorize --n 1024 --variant et [--bo 256 --bi 32 --threads 6 --check]
//!               [--driver lookahead|dag]  # dag = tile-DAG dataflow
//!                                         # runtime (DESIGN.md §17)
//! mlu chol      --n 1024 --variant et [--bo 256 --bi 32 --threads 6 --check]
//!               [--driver lookahead|dag]
//! mlu qr        --n 1024 [--m 2048] --variant et [--bo --bi --threads --check]
//!               [--driver lookahead|dag]
//! mlu solve     --n 512 --prec f32|f64|mixed     # precision-selected solve:
//!               # mixed = f32 factorization + f64 iterative refinement
//!               # to full double-precision backward error (DESIGN.md §12)
//! mlu batch     --sizes 256,192,320 --workers 4 [--kind lu|chol|qr|mix]
//!               [--prec f32|f64] [--check --compare --trace t.json]
//!               [--interleaved]   # route small LU requests through the
//!                                 # SIMD-interleaved fast path (§18)
//! mlu serve     --listen unix:/run/mlu.sock|tcp:host:port [--workers 4]
//!               [--max-pending 64 --max-client 16 --max-dim 8192
//!                --grace-ms 5000 --interleaved]
//!                                   # network daemon; SIGTERM/SIGINT
//!                                   # triggers a graceful drain (§14)
//! mlu sclient   --connect unix:...|tcp:... --count 8 --n 96
//!               [--kind lu|chol|qr|solve|mix --prec f32|f64|mix
//!                --priority 0 --deadline-ms 0 --check
//!                --retry 0 --backoff 100]  # protocol client; --retry
//!                                # reconnects and resubmits unsettled
//!                                # requests after disconnects or
//!                                # transient refusals (jittered
//!                                # exponential backoff)
//! mlu replay    bundle.mrb [--rounds 3 --workers W]
//!               [--sweep steal=off|auto|250|750,static_frac=0.9]
//!               [--out BENCH_replay.json]  # deterministic capture/replay:
//!                                 # certify bitwise results + decision
//!                                 # streams, sweep counterfactual steal
//!                                 # policies through the cost model (§16)
//! mlu trace     --n 2000 --variant mb [--sim] [--out trace.json]
//! mlu fig 14|15|16|17 [--paper] [--out fig.csv]  # simulated paper figures
//! mlu gepp      --m 768 --kmax 256               # real-mode GEPP curve
//! mlu xla       --n 192 --bo 64 [--stepped]      # PJRT artifact demo
//! mlu info
//! ```
//!
//! Global flags: `--params mc,kc,nc` overrides the cache-topology-derived
//! BLIS blocking; `--kernel auto|simd|portable` forces a micro-kernel
//! (results are bitwise identical either way); `--steal
//! off|auto|<fraction>` selects the trailing-update schedule — hybrid
//! static/dynamic tile-stealing with an auto or fixed static fraction,
//! or the central-ticket baseline (also bitwise identical; DESIGN.md
//! §13).
//!
//! `mlu chol` and `mlu qr` run Cholesky / Householder QR through the
//! *same* generic WS+ET look-ahead driver as the LU variants — the
//! factorization-family generalization (DESIGN.md §11).

use malleable_lu::blis::BlisParams;
use malleable_lu::cli::{render_table, Args};
use malleable_lu::factor::{self, FactorKind, LaOpts};
use malleable_lu::lu::{self, LuConfig, Variant};
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::pool::{Crew, Pool};
use malleable_lu::scalar::Scalar;
use malleable_lu::sim::{self, figures, HwModel};
use malleable_lu::solve::{self, SolvePrec};
use malleable_lu::util::{gflops, lu_flops, timed};
use malleable_lu::{runtime, serve, trace};

fn main() {
    let args = Args::from_env();
    apply_kernel_flag(&args);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "factorize" => cmd_factorize(&args),
        "chol" => cmd_factor_kind(FactorKind::Chol, &args),
        "qr" => cmd_factor_kind(FactorKind::Qr, &args),
        "solve" => cmd_solve(&args),
        "batch" => cmd_batch(&args),
        "serve" => cmd_serve(&args),
        "sclient" => cmd_sclient(&args),
        "replay" => cmd_replay(&args),
        "trace" => cmd_trace(&args),
        "fig" => cmd_fig(&args),
        "gepp" => cmd_gepp(&args),
        "xla" => cmd_xla(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!("{}", HELP);
            1
        }
    };
    std::process::exit(code);
}

const HELP: &str = "mlu — malleable thread-level factorizations (see README.md)
commands: factorize | chol | qr | solve | batch | serve | sclient | replay | trace | fig {14,15,16,17} | gepp | xla | info
global flags: --params mc,kc,nc | --kernel auto|simd|portable | --steal off|auto|<fraction>
factor flags: --driver lookahead|dag selects the driver family (dag = tile-DAG dataflow runtime, DESIGN.md §17)
solve flags: --prec f32|f64|mixed (mixed = f32 factor + f64 refinement)
batch flags: --interleaved routes small LU problems through the SIMD-interleaved fast path (DESIGN.md §18)
serve flags: --listen unix:<path>|tcp:<host:port> --workers N --max-pending Q --max-client C --max-dim D --grace-ms G
             --capture out.mrb (record every scheduling decision into a replay bundle, DESIGN.md §16)
             --interleaved (bundle small LU requests into SIMD-interleaved batches, DESIGN.md §18)
sclient flags: --connect <addr> --count N --n SIZE --kind lu|chol|qr|solve|mix --prec f32|f64|mix --check
               --retry N --backoff MS (reconnect + resubmit on disconnects, overloaded/draining rejects, internal failures)
replay: mlu replay bundle.mrb [--rounds N --workers W --sweep steal=off|auto|250,static_frac=0.9 --out BENCH_replay.json]
        re-executes a captured bundle, certifies bitwise results + invariant decision streams (exit 1 on divergence),
        and with --sweep prices the trace under counterfactual steal policies into the --out JSON";

/// Resolve the BLIS blocking: `--params mc,kc,nc` override, else the
/// cache-topology-derived defaults. A malformed override is a hard
/// error — silently measuring under different blocking than requested
/// would corrupt perf experiments.
fn resolve_params(args: &Args) -> BlisParams {
    let s = args.get_str("params", "");
    let mut p = if s.is_empty() {
        BlisParams::auto()
    } else {
        match BlisParams::parse(&s) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("bad --params: {e}");
                std::process::exit(2);
            }
        }
    };
    let steal = args.get_str("steal", "");
    if !steal.is_empty() {
        match malleable_lu::blis::StealPolicy::parse(&steal) {
            Ok(sp) => p.steal = sp,
            Err(e) => {
                eprintln!("bad --steal: {e}");
                std::process::exit(2);
            }
        }
    }
    p
}

/// Apply `--kernel auto|simd|portable` process-wide. An unknown value
/// is a hard error (see [`resolve_params`]).
fn apply_kernel_flag(args: &Args) {
    use malleable_lu::blis::{set_kernel, Kernel};
    match args.get_str("kernel", "auto").as_str() {
        "portable" => set_kernel(Kernel::Portable),
        "simd" => set_kernel(Kernel::Simd),
        "auto" => set_kernel(Kernel::Auto),
        other => {
            eprintln!("unknown --kernel {other:?} (expected auto|simd|portable)");
            std::process::exit(2);
        }
    }
}

fn lu_config(args: &Args) -> LuConfig {
    LuConfig {
        variant: Variant::parse(&args.get_str("variant", "et")).unwrap_or_else(|| {
            eprintln!("unknown variant; using et");
            Variant::EarlyTerm
        }),
        bo: args.get("bo", 256),
        bi: args.get("bi", 32),
        threads: args.get("threads", 6),
        t_pf: args.get("t-pf", 1),
        params: resolve_params(args),
        entry: if args.has("immediate") {
            malleable_lu::pool::EntryPolicy::Immediate
        } else {
            malleable_lu::pool::EntryPolicy::JobBoundary
        },
    }
}

/// Parse `--driver lookahead|dag` (default `lookahead`): which driver
/// family runs the factorization (DESIGN.md §17.6).
fn parse_driver(args: &Args) -> factor::DriverFamily {
    let s = args.get_str("driver", "lookahead");
    factor::DriverFamily::parse(&s).unwrap_or_else(|| {
        eprintln!("unknown --driver {s:?} (expected lookahead|dag)");
        std::process::exit(2);
    })
}

/// Run one factorization through the tile-DAG runtime (`--driver dag`)
/// and print the bench line; shared by `factorize`/`chol`/`qr`.
fn run_dag_kind(kind: FactorKind, args: &Args, a0: &Matrix) -> i32 {
    let (m, n) = (a0.rows(), a0.cols());
    let bo = args.get("bo", 256usize);
    let bi = args.get("bi", 32usize);
    let threads = args.get("threads", 6usize);
    let params = resolve_params(args);
    let pool = Pool::new(threads.saturating_sub(1));
    let mut f = a0.clone();
    let (secs, out) = timed(|| {
        malleable_lu::tilert::factorize_dag(
            kind,
            &pool,
            &params,
            &mut f,
            bo,
            bi,
            &factor::FactorCtl::default(),
        )
    });
    if let Some(e) = &out.error {
        eprintln!("dag {}: {e}", kind.name());
        return 1;
    }
    println!(
        "dag {} m={m} n={n} bo={bo} bi={bi} t={threads}: {secs:.3}s  {:.2} GFLOPS",
        kind.name(),
        gflops(kind.flops(m, n), secs)
    );
    if args.has("check") {
        let r = match kind {
            FactorKind::Lu => naive::lu_residual(a0, &f, &out.ipiv),
            FactorKind::Chol => naive::chol_residual(a0, &f),
            FactorKind::Qr => naive::qr_residual(a0, &f, &out.tau),
        };
        println!("  residual = {r:.3e}");
        if r > 1e-10 {
            eprintln!("RESIDUAL TOO LARGE");
            return 1;
        }
    }
    0
}

fn cmd_factorize(args: &Args) -> i32 {
    let n = args.get("n", 1024usize);
    let seed = args.get("seed", 42u64);
    let a0 = Matrix::random(n, n, seed);
    if parse_driver(args) == factor::DriverFamily::Dag {
        return run_dag_kind(FactorKind::Lu, args, &a0);
    }
    let cfg = lu_config(args);
    let mut f = a0.clone();
    let (secs, out) = timed(|| lu::factorize(&mut f, &cfg, None));
    println!(
        "{} n={n} bo={} bi={} t={}: {:.3}s  {:.2} GFLOPS",
        cfg.variant.name(),
        cfg.bo,
        cfg.bi,
        cfg.threads,
        secs,
        gflops(lu_flops(n, n), secs)
    );
    if let Some(stats) = &out.la_stats {
        println!(
            "  iters={} et_cuts={} ws_fwd={} ws_rev={} panel_widths[..8]={:?}",
            stats.iters,
            stats.et_cuts,
            stats.ws_forward,
            stats.ws_reverse,
            &stats.panel_widths[..stats.panel_widths.len().min(8)]
        );
    }
    if args.has("check") {
        let r = lu::residual(&a0, &f, &out.ipiv);
        println!("  residual ‖PA−LU‖/‖A‖ = {r:.3e}");
        if r > 1e-10 {
            eprintln!("RESIDUAL TOO LARGE");
            return 1;
        }
    }
    0
}

/// Map `--variant la|mb|et` (default `et`) onto the generic look-ahead
/// options shared by every factorization kind.
fn la_opts(args: &Args) -> LaOpts {
    let (malleable, early_term) =
        match args.get_str("variant", "et").to_ascii_lowercase().as_str() {
            "la" => (false, false),
            "mb" => (true, false),
            "et" => (true, true),
            other => {
                eprintln!("unknown look-ahead variant {other:?}; using et");
                (true, true)
            }
        };
    LaOpts {
        malleable,
        early_term,
        entry: if args.has("immediate") {
            malleable_lu::pool::EntryPolicy::Immediate
        } else {
            malleable_lu::pool::EntryPolicy::JobBoundary
        },
        t_pf: args.get("t-pf", 1),
    }
}

/// `mlu chol` / `mlu qr`: run a non-LU kind through the generic WS+ET
/// look-ahead driver.
fn cmd_factor_kind(kind: FactorKind, args: &Args) -> i32 {
    let n = args.get("n", 1024usize);
    let m = if kind == FactorKind::Qr {
        args.get("m", n)
    } else {
        n
    };
    let bo = args.get("bo", 256usize);
    let bi = args.get("bi", 32usize);
    let threads = args.get("threads", 6usize);
    let seed = args.get("seed", 42u64);
    let opts = la_opts(args);
    let params = resolve_params(args);
    let a0 = match kind {
        FactorKind::Chol => Matrix::random_spd(n, seed),
        _ => Matrix::random(m, n, seed),
    };
    if parse_driver(args) == factor::DriverFamily::Dag {
        return run_dag_kind(kind, args, &a0);
    }
    let mut f = a0.clone();
    let pool = Pool::new(threads.saturating_sub(1));
    let (secs, out) = timed(|| {
        factor::factorize_lookahead(kind, &pool, &params, &mut f, bo, bi, &opts, None)
    });
    println!(
        "{} m={m} n={n} bo={bo} bi={bi} t={threads}: {secs:.3}s  {:.2} GFLOPS",
        kind.name(),
        gflops(kind.flops(m, n), secs)
    );
    if let Some(stats) = &out.la_stats {
        println!(
            "  iters={} et_cuts={} ws_fwd={} ws_rev={} panel_widths[..8]={:?}",
            stats.iters,
            stats.et_cuts,
            stats.ws_forward,
            stats.ws_reverse,
            &stats.panel_widths[..stats.panel_widths.len().min(8)]
        );
    }
    if args.has("check") {
        let r = match kind {
            FactorKind::Lu => naive::lu_residual(&a0, &f, &out.ipiv),
            FactorKind::Chol => naive::chol_residual(&a0, &f),
            FactorKind::Qr => naive::qr_residual(&a0, &f, &out.tau),
        };
        println!("  residual = {r:.3e}");
        if r > 1e-10 {
            eprintln!("RESIDUAL TOO LARGE");
            return 1;
        }
    }
    0
}

fn cmd_solve(args: &Args) -> i32 {
    let n = args.get("n", 512usize);
    let prec_s = args.get_str("prec", "f64");
    let Some(prec) = SolvePrec::parse(&prec_s) else {
        eprintln!("unknown --prec {prec_s:?} (expected f32|f64|mixed)");
        return 2;
    };
    let bo = args.get("bo", 256usize);
    let bi = args.get("bi", 32usize);
    let threads = args.get("threads", 6usize);
    let params = resolve_params(args);
    let seed = args.get("seed", 7u64);
    let a0 = Matrix::random_dd(n, seed);
    let x_true: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) - 6.0).collect();
    let mut b = vec![0.0; n];
    for j in 0..n {
        for i in 0..n {
            b[i] += a0[(i, j)] * x_true[j];
        }
    }
    // One crew spanning the whole team, like the blocked variants.
    let pool = Pool::new(threads.saturating_sub(1));
    let mut crew = Crew::new();
    let members = pool.broadcast(|_w| {
        let s = crew.shared();
        move || s.member_loop(malleable_lu::pool::EntryPolicy::JobBoundary)
    });
    let (secs, out) = timed(|| solve::solve_system(&mut crew, &params, prec, &a0, &b, bo, bi));
    crew.disband();
    for h in members {
        h.wait();
    }
    let x = &out.x;
    let err = x
        .iter()
        .zip(&x_true)
        .fold(0.0f64, |acc, (a, b)| acc.max((a - b).abs()));
    println!(
        "solved {n}x{n} [prec={}] in {secs:.3}s ({:.2} GFLOPS): backward error {:.3e}, \
         {} refine sweeps, max |x\u{2212}x*| = {err:.3e}",
        prec.name(),
        gflops(lu_flops(n, n), secs),
        out.backward_error,
        out.refine_iters
    );
    if !out.converged {
        eprintln!("SOLVE DID NOT CONVERGE");
        return 1;
    }
    let tol = prec.expected_backward_error(n);
    if out.backward_error > tol {
        eprintln!("BACKWARD ERROR {:.3e} ABOVE {tol:.3e}", out.backward_error);
        return 1;
    }
    0
}

fn cmd_batch(args: &Args) -> i32 {
    let sizes_s = args.get_str("sizes", "256,192,320,224,160,288,208,256");
    let sizes: Vec<usize> = sizes_s
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    if sizes.is_empty() {
        eprintln!("--sizes must be a comma-separated list of matrix orders");
        return 1;
    }
    let kind_s = args.get_str("kind", "lu");
    let kinds: Vec<FactorKind> = if kind_s == "mix" {
        (0..sizes.len())
            .map(|i| FactorKind::all()[i % FactorKind::all().len()])
            .collect()
    } else {
        match FactorKind::parse(&kind_s) {
            Some(k) => vec![k; sizes.len()],
            None => {
                eprintln!("unknown --kind {kind_s:?} (expected lu|chol|qr|mix)");
                return 1;
            }
        }
    };
    let cfg = serve::ServeConfig {
        workers: args.get("workers", 4usize),
        bo: args.get("bo", 64),
        bi: args.get("bi", 16),
        params: resolve_params(args),
        interleave: args.has("interleaved"),
        ..Default::default()
    };
    let prec_s = args.get_str("prec", "f64");
    match prec_s.as_str() {
        "f64" => {}
        "f32" => return batch_f32(args, &sizes, &kinds, cfg),
        other => {
            eprintln!("unknown --prec {other:?} for batch (expected f32|f64)");
            return 1;
        }
    }
    let total_flops: f64 = sizes
        .iter()
        .zip(&kinds)
        .map(|(&n, k)| k.flops(n, n))
        .sum();
    let mats: Vec<Matrix> = sizes
        .iter()
        .zip(&kinds)
        .enumerate()
        .map(|(i, (&n, &k))| match k {
            FactorKind::Chol => Matrix::random_spd(n, i as u64 + 1),
            _ => Matrix::random(n, n, i as u64 + 1),
        })
        .collect();
    let originals = if args.has("check") {
        Some(mats.clone())
    } else {
        None
    };

    let trace_out = args.get_str("trace", "");
    let rec = if trace_out.is_empty() {
        None
    } else {
        Some(trace::start())
    };
    let server = serve::LuServer::new(cfg);
    let reqs: Vec<serve::LuRequest> = mats
        .into_iter()
        .zip(&kinds)
        .map(|(a, &k)| serve::LuRequest::new(a).with_kind(k))
        .collect();
    let (secs, results) = timed(|| server.factorize_batch(reqs));
    server.shutdown();
    if rec.is_some() {
        trace::stop();
    }
    let batched_g = gflops(total_flops, secs);
    println!(
        "batched {} problems (n={sizes:?}) on {} workers: {secs:.3}s, {batched_g:.2} aggregate GFLOPS",
        results.len(),
        cfg.workers
    );
    for r in &results {
        println!(
            "  req{} {} n={} cols_done={} cancelled={} {:.3}s",
            r.id,
            r.kind.name(),
            r.a.rows(),
            r.cols_done,
            r.cancelled,
            r.secs
        );
    }
    if let Some(origs) = &originals {
        for (r, a0) in results.iter().zip(origs) {
            let res = match r.kind {
                FactorKind::Lu => lu::residual(a0, &r.a, &r.ipiv),
                FactorKind::Chol => naive::chol_residual(a0, &r.a),
                FactorKind::Qr => naive::qr_residual(a0, &r.a, &r.tau),
            };
            if res > 1e-10 {
                eprintln!("req{}: residual {res:.3e} too large", r.id);
                return 1;
            }
        }
        println!("  all residuals OK");
    }
    if let Some(rec) = rec {
        let spans = rec.spans();
        print!("{}", trace::ascii_gantt_requests(&spans, args.get("width", 100)));
        if trace_out != "-" {
            std::fs::write(&trace_out, trace::chrome_json(&spans)).expect("write trace");
            println!("wrote {trace_out} (open in chrome://tracing or Perfetto)");
        }
    }
    if args.has("compare") && kinds.iter().any(|k| *k != FactorKind::Lu) {
        eprintln!("--compare is only meaningful with --kind lu; skipping baseline");
    } else if args.has("compare") {
        // Sequential baseline: same problems one at a time, each with the
        // full team (pool workers + this thread).
        let pool = Pool::new(cfg.workers.saturating_sub(1));
        let lcfg = LuConfig {
            variant: Variant::BlockedRl,
            bo: cfg.bo,
            bi: cfg.bi,
            threads: cfg.workers,
            // Same blocking as the batched run — the speedup must measure
            // scheduling, not a BLIS-parameter difference.
            params: cfg.params,
            ..Default::default()
        };
        let (ssecs, _) = timed(|| {
            for (i, &n) in sizes.iter().enumerate() {
                let mut a = Matrix::random(n, n, i as u64 + 1);
                let _ = lu::factorize(&mut a, &lcfg, Some(&pool));
            }
        });
        let seq_g = gflops(total_flops, ssecs);
        println!(
            "sequential (full pool per problem): {ssecs:.3}s, {seq_g:.2} GFLOPS → batched speedup {:.2}x",
            ssecs / secs
        );
    }
    0
}

/// `mlu batch --prec f32`: the same request stream submitted in single
/// precision through the same queue (residual tolerances scale with
/// `f32::EPSILON`; trace/compare options are f64-only).
fn batch_f32(
    args: &Args,
    sizes: &[usize],
    kinds: &[malleable_lu::factor::FactorKind],
    cfg: serve::ServeConfig,
) -> i32 {
    let total_flops: f64 = sizes.iter().zip(kinds).map(|(&n, k)| k.flops(n, n)).sum();
    let mats: Vec<Mat<f32>> = sizes
        .iter()
        .zip(kinds)
        .enumerate()
        .map(|(i, (&n, &k))| match k {
            FactorKind::Chol => Mat::<f32>::random_spd(n, i as u64 + 1),
            _ => Mat::<f32>::random(n, n, i as u64 + 1),
        })
        .collect();
    let originals = if args.has("check") {
        Some(mats.clone())
    } else {
        None
    };
    let server = serve::LuServer::new(cfg);
    let reqs: Vec<serve::LuRequest<f32>> = mats
        .into_iter()
        .zip(kinds)
        .map(|(a, &k)| serve::LuRequest::new(a).with_kind(k))
        .collect();
    let (secs, results) = timed(|| server.factorize_batch(reqs));
    server.shutdown();
    println!(
        "batched {} f32 problems (n={sizes:?}) on {} workers: {secs:.3}s, {:.2} aggregate GFLOPS",
        results.len(),
        cfg.workers,
        gflops(total_flops, secs)
    );
    for r in &results {
        println!(
            "  req{} {}:f32 n={} cols_done={} cancelled={} {:.3}s",
            r.id,
            r.kind.name(),
            r.a.rows(),
            r.cols_done,
            r.cancelled,
            r.secs
        );
    }
    if let Some(origs) = &originals {
        for (r, a0) in results.iter().zip(origs) {
            let res = match r.kind {
                FactorKind::Lu => naive::lu_residual(a0, &r.a, &r.ipiv),
                FactorKind::Chol => naive::chol_residual(a0, &r.a),
                FactorKind::Qr => naive::qr_residual(a0, &r.a, &r.tau),
            };
            let tol = 16.0 * a0.rows() as f64 * <f32 as Scalar>::EPSILON.to_f64();
            if res > tol {
                eprintln!("req{}: residual {res:.3e} above f32 level {tol:.3e}", r.id);
                return 1;
            }
        }
        println!("  all residuals OK (f32 tolerances)");
    }
    0
}

/// Set by the SIGINT/SIGTERM handler; polled by [`cmd_serve`]'s main
/// loop. The handler is async-signal-safe: it only stores a flag.
static SERVE_STOP: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn serve_on_signal(_sig: i32) {
    SERVE_STOP.store(true, std::sync::atomic::Ordering::Release);
}

/// Install SIGINT (2) and SIGTERM (15) handlers through the C library's
/// `signal` symbol — there is no `libc` crate in the offline registry
/// and `std` exposes no signal API. Linux-only, like the Unix-socket
/// transport itself (DESIGN.md §14.7).
fn install_serve_signal_handlers() {
    extern "C" {
        fn signal(sig: i32, handler: extern "C" fn(i32)) -> isize;
    }
    unsafe {
        signal(2, serve_on_signal); // SIGINT
        signal(15, serve_on_signal); // SIGTERM
    }
}

/// `mlu serve`: bind the network daemon and block until SIGTERM/SIGINT,
/// then drain gracefully — stop accepting, finish or ET in-flight work,
/// flush every response — before shutting the compute pool down
/// (DESIGN.md §14).
fn cmd_serve(args: &Args) -> i32 {
    use malleable_lu::serve::{admission::AdmissionCfg, net};
    let listen = args.get_str("listen", "tcp:127.0.0.1:7070");
    let addr = match net::BindAddr::parse(&listen) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad --listen: {e}");
            return 2;
        }
    };
    let net_cfg = net::NetConfig {
        serve: serve::ServeConfig {
            workers: args.get("workers", 4usize),
            bo: args.get("bo", 64),
            bi: args.get("bi", 16),
            params: resolve_params(args),
            interleave: args.has("interleaved"),
            ..Default::default()
        },
        admission: AdmissionCfg {
            max_pending: args.get("max-pending", 64usize),
            max_client_inflight: args.get("max-client", 16usize),
            max_dim: args.get("max-dim", 8192usize),
        },
        ..Default::default()
    };
    let grace = std::time::Duration::from_millis(args.get("grace-ms", 5000u64));
    let workers = net_cfg.serve.workers;
    // Snapshot the serve config before `bind` takes ownership — the
    // capture bundle records it so replay can rebuild the same server.
    let capture_path = args.get_str("capture", "");
    let bundle_cfg = malleable_lu::replay::BundleCfg::from_serve(&net_cfg.serve);
    if !capture_path.is_empty() && !malleable_lu::replay::capture::start() {
        eprintln!("--capture: another capture is already active in this process");
        return 1;
    }
    let daemon = match net::ServeDaemon::bind(&addr, net_cfg) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("cannot bind {addr}: {e}");
            return 1;
        }
    };
    println!(
        "mlu serve: listening on {} ({workers} workers); SIGTERM or SIGINT drains",
        daemon.local_addr()
    );
    install_serve_signal_handlers();
    while !SERVE_STOP.load(std::sync::atomic::Ordering::Acquire) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("mlu serve: draining (grace {} ms)", grace.as_millis());
    daemon.drain(grace);
    daemon.shutdown();
    if !capture_path.is_empty() {
        match malleable_lu::replay::capture::stop() {
            Some((decisions, mut requests)) => {
                // Submission order = id order (ids are dense from 0).
                requests.sort_by_key(|r| r.id);
                let bundle = malleable_lu::replay::Bundle {
                    cfg: bundle_cfg,
                    requests,
                    decisions,
                };
                let bytes = malleable_lu::replay::bundle::encode(&bundle);
                if let Err(e) = std::fs::write(&capture_path, &bytes) {
                    eprintln!("--capture: cannot write {capture_path}: {e}");
                    return 1;
                }
                println!(
                    "mlu serve: captured {} requests / {} decisions into {capture_path} ({} B)",
                    bundle.requests.len(),
                    bundle.decisions.len(),
                    bytes.len()
                );
            }
            None => {
                eprintln!("--capture: recorder vanished (no bundle written)");
                return 1;
            }
        }
    }
    let s = daemon.stats();
    println!(
        "mlu serve: done — conns={} admitted={} delivered={} reaped={} \
         rejected(overloaded={} too_large={} draining={}) malformed={} oversized={} watchdog={}",
        s.conns_accepted,
        s.admission.admitted,
        s.delivered,
        s.reaped,
        s.admission.rejected_overloaded,
        s.admission.rejected_too_large,
        s.admission.rejected_draining,
        s.malformed,
        s.oversized_frames,
        s.watchdog_fired
    );
    // The drain invariant (DESIGN.md §14.6): every admitted request was
    // answered exactly once or reaped against a vanished client.
    if s.admission.admitted != s.delivered + s.reaped {
        eprintln!("DRAIN INVARIANT VIOLATED: admitted != delivered + reaped");
        return 1;
    }
    0
}

/// `mlu replay bundle.mrb`: re-execute a captured serve run and certify
/// it (DESIGN.md §16.4); `--sweep` additionally prices the trace under
/// counterfactual steal policies into `--out` (§16.6). Exit 1 when
/// certification is refused — the replay regression suite keys on it.
fn cmd_replay(args: &Args) -> i32 {
    use malleable_lu::replay::{bundle, parse_sweep, run_replay, run_sweep};
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: mlu replay <bundle.mrb> [--rounds N --workers W --sweep SPEC --out FILE]");
        return 2;
    };
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return 1;
        }
    };
    let bundle = match bundle::decode(&bytes) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    println!(
        "replay: {path} — {} requests, {} decisions, captured on {} workers (steal {})",
        bundle.requests.len(),
        bundle.decisions.len(),
        bundle.cfg.workers,
        bundle.cfg.steal.name()
    );
    let rounds = args.get("rounds", 1usize);
    let workers = {
        let w = args.get("workers", 0usize);
        (w > 0).then_some(w)
    };
    let report = match run_replay(&bundle, rounds, workers) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("replay failed: {e}");
            return 1;
        }
    };
    print!("{}", report.render());
    let sweep_spec = args.get_str("sweep", "");
    if !sweep_spec.is_empty() {
        let points = match parse_sweep(&sweep_spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("bad --sweep: {e}");
                return 2;
            }
        };
        let doc = run_sweep(&bundle, &points);
        let out = args.get_str("out", "BENCH_replay.json");
        if let Err(e) = std::fs::write(&out, doc.dump()) {
            eprintln!("cannot write {out}: {e}");
            return 1;
        }
        if let Some(rows) = doc.get("points").and_then(|p| p.as_arr()) {
            println!("sweep: {} policy points -> {out}", rows.len());
            for r in rows {
                let name = r.get("policy").and_then(|v| v.as_str()).unwrap_or("?");
                let gf = r.get("gflops").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let dgf = r
                    .get("delta_gflops_pct")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0);
                let dlat = r
                    .get("delta_latency_pct")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0);
                println!(
                    "  {name:<24} {gf:8.2} GFLOPS  Δgflops {dgf:+7.2}%  Δlatency {dlat:+7.2}%"
                );
            }
        }
    }
    if report.certified_ok() {
        0
    } else {
        1
    }
}

/// What `mlu sclient` remembers per in-flight request so it can verify
/// the response (`--check`) and report latency.
enum SentReq {
    /// Factorization submitted in f64.
    F64 {
        /// Requested kind.
        kind: FactorKind,
        /// Original matrix for the residual check.
        a0: Matrix,
    },
    /// Factorization submitted in f32.
    F32 {
        /// Requested kind.
        kind: FactorKind,
        /// Original matrix for the residual check.
        a0: Mat<f32>,
    },
    /// Mixed-precision solve of an order-`n` system with x* = 1.
    Solve {
        /// System order (for the backward-error tolerance).
        n: usize,
    },
}

/// One `mlu sclient` request, generated up front and kept until it is
/// *settled* — answered, terminally failed/rejected, or out of retries.
/// Keeping the wire payload lets a retry resubmit it verbatim after a
/// reconnect.
struct ReqSpec {
    info: SentReq,
    payload: ReqPayload,
}

enum ReqPayload {
    Factor(serve::proto::FactorReq),
    Solve(serve::proto::SolveReq),
}

/// Deterministically jittered exponential backoff: attempt `k`
/// (1-based) sleeps somewhere in `[base·2^(k-1)/2, base·2^(k-1)]` ms
/// (exponent capped at 2^6). The jitter comes from a fixed-seed LCG, so
/// runs are reproducible while scripted reconnect storms still spread
/// out instead of hammering the daemon in lock-step.
fn jittered_backoff_ms(base: u64, attempt: usize, rng: &mut u64) -> u64 {
    *rng = rng
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407);
    let span = base.saturating_mul(1 << attempt.saturating_sub(1).min(6)).max(1);
    span / 2 + (*rng >> 33) % (span / 2 + 1)
}

/// `mlu sclient`: submit a pipelined burst of requests to a running
/// daemon and report per-request latency; with `--check`, verify
/// residuals / backward errors client-side. `--retry N` survives
/// daemon restarts and transient refusals: a dropped connection, an
/// `overloaded`/`draining` reject, or an `internal` failure reconnects
/// (with `--backoff` jittered exponential delay) and resubmits only the
/// still-unsettled requests. Numerical failures (`singular`,
/// `non-finite`, `unsupported`) are terminal — retrying cannot fix the
/// input.
fn cmd_sclient(args: &Args) -> i32 {
    use malleable_lu::serve::client::{ServeClient, WireEvent};
    use malleable_lu::serve::net::BindAddr;
    use malleable_lu::serve::proto;
    use std::time::Instant;

    let addr_s = args.get_str("connect", "tcp:127.0.0.1:7070");
    let addr = match BindAddr::parse(&addr_s) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad --connect: {e}");
            return 2;
        }
    };
    let count = args.get("count", 8usize);
    let n = args.get("n", 96usize);
    let kind_s = args.get_str("kind", "mix");
    let prec_s = args.get_str("prec", "f64");
    if !matches!(prec_s.as_str(), "f64" | "f32" | "mix") {
        eprintln!("unknown --prec {prec_s:?} (expected f32|f64|mix)");
        return 2;
    }
    let priority = args.get("priority", 0u8);
    let deadline_ms = args.get("deadline-ms", 0u32);
    let bo = args.get("bo", 0u16);
    let bi = args.get("bi", 0u16);
    let check = args.has("check");
    let retry = args.get("retry", 0usize);
    let backoff = args.get("backoff", 100u64);

    // Generate every request up front; the specs outlive any one
    // connection so a retry can resubmit the unsettled ones verbatim.
    let mut specs: Vec<Option<ReqSpec>> = Vec::with_capacity(count);
    for i in 0..count {
        let seed = i as u64 + 1;
        let kname = if kind_s == "mix" {
            ["lu", "chol", "qr", "solve"][i % 4]
        } else {
            kind_s.as_str()
        };
        let spec = if kname == "solve" {
            // Diagonally-dominant system with x* = 1 (b = A·1).
            let a = Matrix::random_dd(n, seed);
            let mut b = vec![0.0; n];
            for j in 0..n {
                for r in 0..n {
                    b[r] += a[(r, j)];
                }
            }
            ReqSpec {
                info: SentReq::Solve { n },
                payload: ReqPayload::Solve(proto::SolveReq {
                    prec: SolvePrec::Mixed,
                    priority,
                    deadline_ms,
                    bo,
                    bi,
                    a,
                    b,
                }),
            }
        } else {
            let Some(kind) = FactorKind::parse(kname) else {
                eprintln!("unknown --kind {kname:?} (expected lu|chol|qr|solve|mix)");
                return 2;
            };
            let use_f32 = match prec_s.as_str() {
                "f32" => true,
                "mix" => i % 2 == 1,
                _ => false,
            };
            if use_f32 {
                let a0 = match kind {
                    FactorKind::Chol => Mat::<f32>::random_spd(n, seed),
                    _ => Mat::<f32>::random(n, n, seed),
                };
                ReqSpec {
                    info: SentReq::F32 { kind, a0: a0.clone() },
                    payload: ReqPayload::Factor(proto::FactorReq {
                        kind,
                        priority,
                        deadline_ms,
                        bo,
                        bi,
                        a: proto::WireMat::F32(a0),
                    }),
                }
            } else {
                let a0 = match kind {
                    FactorKind::Chol => Matrix::random_spd(n, seed),
                    _ => Matrix::random(n, n, seed),
                };
                ReqSpec {
                    info: SentReq::F64 { kind, a0: a0.clone() },
                    payload: ReqPayload::Factor(proto::FactorReq {
                        kind,
                        priority,
                        deadline_ms,
                        bo,
                        bi,
                        a: proto::WireMat::F64(a0),
                    }),
                }
            }
        };
        specs.push(Some(spec));
    }

    let t0 = Instant::now();
    let mut failures = 0usize;
    let mut rejects = 0usize;
    let mut attempt = 0usize;
    let mut rng: u64 = 0x5851_f42d_4c95_7f2d;
    loop {
        let mut client = match ServeClient::connect(&addr) {
            Ok(c) => c,
            Err(e) => {
                if attempt < retry {
                    attempt += 1;
                    let ms = jittered_backoff_ms(backoff, attempt, &mut rng);
                    eprintln!("connect {addr}: {e}; retry {attempt}/{retry} in {ms} ms");
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                    continue;
                }
                eprintln!("connect {addr}: {e}");
                return 1;
            }
        };
        // Pipelined submission of everything still unsettled, then
        // drain responses in whatever completion order the daemon
        // produces.
        let mut inflight: std::collections::HashMap<u64, (usize, Instant)> =
            std::collections::HashMap::new();
        let mut conn_lost = false;
        for (idx, slot) in specs.iter().enumerate() {
            let Some(spec) = slot else { continue };
            let sub = match &spec.payload {
                ReqPayload::Factor(q) => client.submit_factor(q),
                ReqPayload::Solve(q) => client.submit_solve(q),
            };
            match sub {
                Ok(id) => {
                    inflight.insert(id, (idx, Instant::now()));
                }
                Err(e) => {
                    eprintln!("submit failed: {e}");
                    conn_lost = true;
                    break;
                }
            }
        }
        while !conn_lost && !inflight.is_empty() {
            let ev = match client.recv() {
                Ok(ev) => ev,
                Err(e) => {
                    eprintln!("recv failed: {e}");
                    conn_lost = true;
                    break;
                }
            };
            match ev {
                WireEvent::Factor { id, resp } => {
                    let Some((idx, t)) = inflight.remove(&id) else {
                        eprintln!("response for unknown id {id}");
                        failures += 1;
                        continue;
                    };
                    let ms = t.elapsed().as_secs_f64() * 1e3;
                    println!(
                        "  req{id} {}:{} n={} cols_done={} cancelled={} {ms:.1} ms",
                        resp.kind.name(),
                        resp.a.prec_name(),
                        resp.a.cols(),
                        resp.cols_done,
                        resp.cancelled
                    );
                    if check {
                        match specs[idx].as_ref() {
                            Some(s) if sclient_check_factor(id, &s.info, &resp) => {}
                            _ => failures += 1,
                        }
                    }
                    specs[idx] = None;
                }
                WireEvent::Solve { id, resp } => {
                    let Some((idx, t)) = inflight.remove(&id) else {
                        eprintln!("response for unknown id {id}");
                        failures += 1;
                        continue;
                    };
                    let ms = t.elapsed().as_secs_f64() * 1e3;
                    println!(
                        "  req{id} solve:{} n={} refine_iters={} berr={:.3e} {ms:.1} ms",
                        resp.prec.name(),
                        resp.x.len(),
                        resp.refine_iters,
                        resp.backward_error
                    );
                    if check {
                        let tol = SolvePrec::Mixed.expected_backward_error(n);
                        if resp.cancelled || !resp.converged || resp.backward_error > tol {
                            eprintln!(
                                "req{id}: solve check failed (cancelled={} converged={} berr={:.3e} tol={tol:.3e})",
                                resp.cancelled,
                                resp.converged,
                                resp.backward_error
                            );
                            failures += 1;
                        }
                    }
                    specs[idx] = None;
                }
                WireEvent::Failed { id, failure } => {
                    let Some((idx, _)) = inflight.remove(&id) else {
                        eprintln!("failure for unknown id {id}");
                        failures += 1;
                        continue;
                    };
                    // Only internal faults (a panicked leader) are worth
                    // retrying; numerical failures are properties of the
                    // input and will recur verbatim.
                    if failure.code == proto::FailCode::Internal && attempt < retry {
                        eprintln!(
                            "  req{id} FAILED {}: {} — will retry",
                            failure.code.name(),
                            failure.reason
                        );
                    } else {
                        eprintln!(
                            "  req{id} FAILED {}: {} (detail={})",
                            failure.code.name(),
                            failure.reason,
                            failure.detail
                        );
                        failures += 1;
                        specs[idx] = None;
                    }
                }
                WireEvent::Rejected { id, reject } => {
                    if id == 0 {
                        eprintln!(
                            "session rejected {}: {}",
                            reject.code.name(),
                            reject.reason
                        );
                        conn_lost = true;
                        break;
                    }
                    let Some((idx, _)) = inflight.remove(&id) else {
                        eprintln!("reject for unknown id {id}");
                        rejects += 1;
                        continue;
                    };
                    let transient = matches!(
                        reject.code,
                        proto::RejectCode::Overloaded | proto::RejectCode::Draining
                    );
                    if transient && attempt < retry {
                        eprintln!(
                            "  req{id} REJECTED {}: {} — will retry",
                            reject.code.name(),
                            reject.reason
                        );
                    } else {
                        eprintln!(
                            "  req{id} REJECTED {}: {}",
                            reject.code.name(),
                            reject.reason
                        );
                        rejects += 1;
                        specs[idx] = None;
                    }
                }
            }
        }
        if !conn_lost {
            let _ = client.goodbye();
        }
        let outstanding = specs.iter().filter(|s| s.is_some()).count();
        if outstanding == 0 {
            break;
        }
        if attempt >= retry {
            eprintln!("sclient: {outstanding} request(s) unresolved after {attempt} retries");
            failures += outstanding;
            break;
        }
        attempt += 1;
        let ms = jittered_backoff_ms(backoff, attempt, &mut rng);
        eprintln!("sclient: retrying {outstanding} request(s), attempt {attempt}/{retry} in {ms} ms");
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "sclient: {count} requests in {secs:.3}s ({rejects} rejected, {failures} failures, {attempt} reconnect attempts)"
    );
    if failures > 0 || rejects > 0 {
        return 1;
    }
    0
}

/// Client-side residual verification for one factorization response.
fn sclient_check_factor(id: u64, info: &SentReq, resp: &serve::proto::FactorResp) -> bool {
    use malleable_lu::serve::proto::{WireMat, WireVec};
    if resp.cancelled {
        eprintln!("req{id}: cancelled (cols_done={})", resp.cols_done);
        return false;
    }
    let ipiv: Vec<usize> = resp.ipiv.iter().map(|&p| p as usize).collect();
    let (res, tol) = match (info, &resp.a) {
        (SentReq::F64 { kind, a0 }, WireMat::F64(f)) => {
            let r = match kind {
                FactorKind::Lu => naive::lu_residual(a0, f, &ipiv),
                FactorKind::Chol => naive::chol_residual(a0, f),
                FactorKind::Qr => match &resp.tau {
                    WireVec::F64(tau) => naive::qr_residual(a0, f, tau),
                    WireVec::F32(_) => f64::NAN,
                },
            };
            (r, 1e-10)
        }
        (SentReq::F32 { kind, a0 }, WireMat::F32(f)) => {
            let r = match kind {
                FactorKind::Lu => naive::lu_residual(a0, f, &ipiv),
                FactorKind::Chol => naive::chol_residual(a0, f),
                FactorKind::Qr => match &resp.tau {
                    WireVec::F32(tau) => naive::qr_residual(a0, f, tau),
                    WireVec::F64(_) => f64::NAN,
                },
            };
            let tol = 16.0 * a0.rows() as f64 * <f32 as Scalar>::EPSILON.to_f64();
            (r, tol)
        }
        _ => {
            eprintln!("req{id}: response precision does not match the request");
            return false;
        }
    };
    if res.is_nan() || res > tol {
        eprintln!("req{id}: residual {res:.3e} above {tol:.3e}");
        return false;
    }
    true
}

fn cmd_trace(args: &Args) -> i32 {
    let n = args.get("n", 2000usize);
    let cfg = lu_config(args);
    let width = args.get("width", 100usize);
    let spans = if args.has("sim") || args.get("n", 0usize) > 4000 {
        // Virtual-time trace on the simulated 6-core testbed.
        let v = sim::SimVariant::parse(&args.get_str("variant", "mb"))
            .unwrap_or(sim::SimVariant::Mb);
        let out = sim::simulate(
            &HwModel::default(),
            v,
            n,
            cfg.bo,
            cfg.bi,
            cfg.threads,
            cfg.t_pf,
            true,
        );
        println!(
            "[sim] {} n={n} bo={}: {:.3}s virtual, {:.1} GFLOPS, {} iters, {} cuts",
            v.name(),
            cfg.bo,
            out.time,
            out.gflops,
            out.iters,
            out.et_cuts
        );
        out.spans
    } else {
        let rec = trace::start();
        let mut a = Matrix::random(n, n, 1);
        let (secs, _) = timed(|| lu::factorize(&mut a, &cfg, None));
        trace::stop();
        println!(
            "[real] {} n={n}: {:.3}s wall ({} threads, 1-core host: overlap is logical)",
            cfg.variant.name(),
            secs,
            cfg.threads
        );
        rec.spans()
    };
    print!("{}", trace::ascii_gantt(&spans, width));
    let out_path = args.get_str("out", "");
    if !out_path.is_empty() {
        std::fs::write(&out_path, trace::chrome_json(&spans)).expect("write trace");
        println!("wrote {out_path} (open in chrome://tracing or Perfetto)");
    }
    0
}

fn cmd_fig(args: &Args) -> i32 {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("16");
    let hw = HwModel::default();
    let grids = if args.has("paper") {
        figures::Grids::paper()
    } else {
        figures::Grids::quick()
    };
    let t = args.get("threads", 6usize);
    let table = match which {
        "14" => {
            let left = figures::fig14_gepp(&hw, &grids);
            let right = figures::fig14_ratio(&hw, &grids);
            print!("{}", render_table(&left));
            print!("{}", render_table(&right));
            let out = args.get_str("out", "");
            if !out.is_empty() {
                std::fs::write(&out, format!("{}{}", left.to_csv(), right.to_csv()))
                    .expect("write csv");
            }
            return 0;
        }
        "15" => figures::fig15_optimal_b(&hw, &grids, t),
        "16" => figures::fig16_variants(&hw, &grids, t),
        "17" => figures::fig17_et_vs_os(&hw, &grids, t),
        _ => {
            eprintln!("unknown figure {which}; expected 14|15|16|17");
            return 1;
        }
    };
    print!("{}", render_table(&table));
    let out = args.get_str("out", "");
    if !out.is_empty() {
        std::fs::write(&out, table.to_csv()).expect("write csv");
        println!("wrote {out}");
    }
    0
}

fn cmd_gepp(args: &Args) -> i32 {
    // Real-mode GEPP curve on this host (absolute numbers are 1-core
    // container numbers; the paper-scale curve comes from `fig 14`).
    let m = args.get("m", 768usize);
    let n = args.get("n", m);
    let kmax = args.get("kmax", 256usize);
    let step = args.get("step", 32usize);
    let reps = args.get("reps", 3usize);
    let params = resolve_params(args);
    println!("k,gflops (real 1-thread GEPP, m={m} n={n})");
    let mut k = step;
    while k <= kmax {
        let a = Matrix::random(m, k, 1);
        let b = Matrix::random(k, n, 2);
        let mut c = Matrix::zeros(m, n);
        let mut crew = malleable_lu::pool::Crew::new();
        let stats = malleable_lu::util::stats::bench_seconds(1, reps, || {
            malleable_lu::blis::gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
        });
        println!(
            "{k},{:.2}",
            gflops(malleable_lu::util::gemm_flops(m, n, k), stats.median)
        );
        k += step;
    }
    0
}

fn cmd_xla(args: &Args) -> i32 {
    let dir = args.get_str("artifacts", "artifacts");
    let n = args.get("n", 192usize);
    let bo = args.get("bo", 64usize);
    let rt = match runtime::Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot open artifacts: {e:#}");
            return 1;
        }
    };
    println!("artifacts: {}", rt.available().join(", "));
    let a = Matrix::random(n, n, 5);
    let run = if args.has("stepped") {
        runtime::xla_lu::factorize_stepped(&rt, &a, bo)
    } else {
        runtime::xla_lu::factorize_full(&rt, &a, bo)
    };
    match run {
        Ok((f, piv)) => {
            let r = malleable_lu::matrix::naive::lu_residual(&a, &f, &piv);
            println!("LU_XLA n={n} bo={bo}: residual {r:.3e}");
            match runtime::xla_lu::cross_validate(&rt, &a, bo, 16) {
                Ok((diff, piv_eq)) => {
                    println!(
                        "cross-check vs rust BLIS: max|Δ|={diff:.3e} pivots_equal={piv_eq}"
                    );
                    i32::from(r > 1e-10 || diff > 1e-9 || !piv_eq)
                }
                Err(e) => {
                    eprintln!("cross-validate failed: {e:#}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("LU_XLA failed: {e:#}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    let hw = HwModel::default();
    println!("malleable-lu {}", env!("CARGO_PKG_VERSION"));
    println!(
        "simulated testbed: {} cores, DGEMM peak {:.1} GFLOPS, GEPP(256) {:.1} GFLOPS",
        hw.cores,
        hw.machine_peak(),
        hw.gepp_gflops(256, hw.cores)
    );
    match malleable_lu::blis::CacheInfo::detect() {
        Some(c) => println!(
            "cache topology: L1d {} KiB, L2 {} KiB, L3 {} KiB",
            c.l1d / 1024,
            c.l2 / 1024,
            c.l3 / 1024
        ),
        None => println!("cache topology: unavailable (using Haswell-class defaults)"),
    }
    println!(
        "BLIS params (auto): {:?} (MR={} NR={}); override with --params mc,kc,nc",
        BlisParams::auto(),
        malleable_lu::blis::params::MR,
        malleable_lu::blis::params::NR
    );
    println!(
        "micro-kernel: {} (simd available: {})",
        malleable_lu::blis::micro::active_kernel_name(),
        malleable_lu::blis::micro::simd_available()
    );
    let pool = Pool::new(2);
    println!("pool smoke: {} workers ok", pool.workers());
    0
}
