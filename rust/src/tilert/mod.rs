//! §tilert — a **tile-DAG dataflow runtime** (DESIGN.md §17), the repo's
//! third driver family next to the blocked and WS+ET look-ahead drivers
//! of [`crate::factor`].
//!
//! The paper's headline experiment pits its malleable thread-level WS+ET
//! look-ahead against a task-parallel runtime-based LU (OmpSs). This
//! module supplies the runtime side of that comparison as a *general*
//! tile-DAG engine in the style of Buttari, Langou, Kurzak & Dongarra's
//! tiled-algorithm/dataflow model:
//!
//! - [`TileGrid`] — a 2D block layout over a column-major [`MatMut`],
//!   handing out [`Tile`] handles with `(i, j)` coordinates. Tiles are
//!   *views*: no data is copied or re-laid-out.
//! - [`Access`] — per-task access declarations ([`Access::In`],
//!   [`Access::Out`], [`Access::InOut`]) from which [`DagBuilder`]
//!   infers dependency edges automatically (last-writer RAW/WAW edges
//!   plus a readers barrier for WAR), replacing
//!   [`crate::taskrt::GraphBuilder`]'s manual edge lists.
//! - [`DagShared`] — a ready-queue scheduler with deterministic
//!   `(priority desc, submit-seq asc)` grant order, executing on the
//!   existing [`Pool`]/crew substrate. Every executor owns a private
//!   sequential [`Crew`] handed to task bodies, so each task's kernels
//!   run the exact per-element operation chains of the blocked driver.
//! - **Crew malleability** — executors can [`DagSlot::attach`] *while a
//!   DAG is draining* (the serve layer's Worker Sharing), and every
//!   executor re-checks its lease between tasks, retiring cleanly at a
//!   task boundary when the lease is revoked (DESIGN.md §17.3).
//!
//! The factorization instantiation (tiled LU/Cholesky/QR through the
//! [`crate::factor::Factorization`] trait) lives in [`factor`], and is
//! reachable through `mlu factorize --driver dag` and per-request
//! driver-family routing in [`crate::serve`].
//!
//! **Determinism.** A task runs exactly once, its body is sequential,
//! and the dependency edges force every ordering that could affect a
//! bit. Executor count and grant interleaving therefore cannot change
//! the result — the same argument, one level up, as the crew-size
//! invariance of the malleable BLAS (DESIGN.md §8). Task *grants* are
//! still recorded by capture as an environmental decision kind
//! ([`crate::replay::capture::DecisionKind::TaskGrant`]) so `mlu replay`
//! can show the schedule without certifying against it.

pub mod factor;

pub use factor::{factorize_dag, factorize_dag_shared, DriverFamily};

use crate::blis::PackArena;
use crate::matrix::MatMut;
use crate::pool::{Crew, Pool};
use crate::replay::capture::{self, DecisionKind};
use crate::scalar::Scalar;
use std::collections::{BTreeSet, HashMap};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Handle to tile `(i, j)` of a [`TileGrid`]. A tile identifies a block
/// of the underlying matrix for dependency tracking; it carries no data.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct Tile {
    /// Tile row (block-row index).
    pub i: usize,
    /// Tile column (block-column index).
    pub j: usize,
}

/// A 2D block layout over an `m × n` matrix with square-ish tiles of
/// side `ts` (edge tiles are smaller). Column-major panels map onto
/// tile columns without copying: [`TileGrid::view`] is a plain
/// [`MatMut::sub`].
#[derive(Copy, Clone, Debug)]
pub struct TileGrid {
    m: usize,
    n: usize,
    ts: usize,
}

impl TileGrid {
    /// Layout for an `m × n` matrix with tile side `ts` (clamped to 1).
    pub fn new(m: usize, n: usize, ts: usize) -> Self {
        Self { m, n, ts: ts.max(1) }
    }

    /// Tile side length.
    pub fn tile_size(&self) -> usize {
        self.ts
    }

    /// Number of tile rows (`⌈m / ts⌉`).
    pub fn tile_rows(&self) -> usize {
        self.m.div_ceil(self.ts)
    }

    /// Number of tile columns (`⌈n / ts⌉`).
    pub fn tile_cols(&self) -> usize {
        self.n.div_ceil(self.ts)
    }

    /// The handle for tile `(i, j)`; panics when out of range.
    pub fn tile(&self, i: usize, j: usize) -> Tile {
        assert!(i < self.tile_rows() && j < self.tile_cols(), "tile ({i},{j}) out of range");
        Tile { i, j }
    }

    /// Element rows covered by tile row `i`, as `(start, len)`.
    pub fn row_span(&self, i: usize) -> (usize, usize) {
        let lo = i * self.ts;
        (lo, self.ts.min(self.m - lo))
    }

    /// Element columns covered by tile column `j`, as `(start, len)`.
    pub fn col_span(&self, j: usize) -> (usize, usize) {
        let lo = j * self.ts;
        (lo, self.ts.min(self.n - lo))
    }

    /// A mutable view of tile `t` of `a` — no copy, column-major stride
    /// preserved ([`MatMut::sub`]).
    pub fn view<S: Scalar>(&self, a: MatMut<S>, t: Tile) -> MatMut<S> {
        let (i0, mh) = self.row_span(t.i);
        let (j0, nw) = self.col_span(t.j);
        a.sub(i0, j0, mh, nw)
    }

    /// Tile handles of one tile column `j`, rows `i0..` — the shape a
    /// panel task declares (`InOut` on the panel's tile column).
    pub fn col_tiles(&self, j: usize, i0: usize) -> Vec<Tile> {
        (i0..self.tile_rows()).map(|i| self.tile(i, j)).collect()
    }
}

/// How a task touches one tile. The builder turns these into edges:
/// a read depends on the tile's last writer; a write additionally
/// barriers behind every reader since that writer (WAR) and becomes the
/// new last writer (WAW). `Out` and `InOut` infer the same edges — the
/// distinction is documentation of intent (a pure `Out` task overwrites
/// the tile without consuming it).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Access {
    /// The task reads the tile.
    In(Tile),
    /// The task overwrites the tile without reading it.
    Out(Tile),
    /// The task reads and writes the tile.
    InOut(Tile),
}

/// A task body: runs on exactly one executor, which lends the task its
/// private sequential [`Crew`] for kernel calls.
pub type TaskFn = Box<dyn FnOnce(&mut Crew) + Send + 'static>;

struct TaskBuild {
    name: String,
    priority: i32,
    run: TaskFn,
    deps: Vec<usize>,
}

#[derive(Default)]
struct TileTrack {
    last_writer: Option<usize>,
    readers: Vec<usize>,
}

/// Incremental DAG construction with automatic dependency inference
/// from per-task [`Access`] declarations (DESIGN.md §17.1).
///
/// Tasks are submitted in program order; for each declared tile access
/// the builder consults the tile's tracking state (last writer + readers
/// since that write) and inserts exactly the RAW/WAW/WAR edges the
/// access requires. Manual edge lists — the [`crate::taskrt`] interface
/// — are not expressible here by design.
#[derive(Default)]
pub struct DagBuilder {
    tasks: Vec<TaskBuild>,
    tiles: HashMap<(usize, usize), TileTrack>,
}

impl DagBuilder {
    /// An empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of tasks submitted so far.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether no tasks have been submitted.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Submit a task. `priority` breaks ready-queue ties (higher runs
    /// first; submit order breaks priority ties), `accesses` declares
    /// every tile the body touches, and the returned id is the task's
    /// submit sequence number.
    pub fn submit(
        &mut self,
        name: impl Into<String>,
        priority: i32,
        accesses: &[Access],
        run: impl FnOnce(&mut Crew) + Send + 'static,
    ) -> usize {
        let id = self.tasks.len();
        let mut deps = BTreeSet::new();
        for &acc in accesses {
            match acc {
                Access::In(t) => {
                    let tr = self.tiles.entry((t.i, t.j)).or_default();
                    if let Some(w) = tr.last_writer {
                        deps.insert(w);
                    }
                    if tr.readers.last() != Some(&id) {
                        tr.readers.push(id);
                    }
                }
                Access::Out(t) | Access::InOut(t) => {
                    let tr = self.tiles.entry((t.i, t.j)).or_default();
                    if let Some(w) = tr.last_writer {
                        deps.insert(w);
                    }
                    for &r in &tr.readers {
                        deps.insert(r);
                    }
                    tr.readers.clear();
                    tr.last_writer = Some(id);
                }
            }
        }
        deps.remove(&id); // In + Out of the same tile in one task
        self.tasks.push(TaskBuild {
            name: name.into(),
            priority,
            run: Box::new(run),
            deps: deps.into_iter().collect(),
        });
        id
    }

    /// Freeze the builder into an executable [`Dag`].
    pub fn build(self) -> Dag {
        let n = self.tasks.len();
        let mut dependents = vec![Vec::new(); n];
        let mut missing = Vec::with_capacity(n);
        for (id, t) in self.tasks.iter().enumerate() {
            missing.push(AtomicUsize::new(t.deps.len()));
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }
        let slots = self
            .tasks
            .into_iter()
            .map(|t| TaskSlot {
                name: t.name,
                priority: t.priority,
                run: Mutex::new(Some(t.run)),
            })
            .collect();
        Dag {
            tasks: slots,
            dependents,
            missing,
        }
    }
}

struct TaskSlot {
    name: String,
    priority: i32,
    run: Mutex<Option<TaskFn>>,
}

/// A frozen task graph ready for execution (see [`Dag::into_shared`]).
pub struct Dag {
    tasks: Vec<TaskSlot>,
    dependents: Vec<Vec<usize>>,
    missing: Vec<AtomicUsize>,
}

impl Dag {
    /// Number of tasks in the graph.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the graph has no tasks.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Dependency count of task `id` (diagnostics and tests).
    pub fn dep_count(&self, id: usize) -> usize {
        self.missing[id].load(Ordering::Relaxed)
    }

    /// Wrap the graph in its scheduler state, ready for executors.
    /// `stop` is an optional external cancel flag every executor polls
    /// between tasks (the factorization layer's fatal-error fuse);
    /// `capture_req` tags task-grant capture records with a serve
    /// request id ([`NO_REQ`] suppresses them).
    pub fn into_shared(self, stop: Option<Arc<AtomicBool>>, capture_req: u64) -> Arc<DagShared> {
        let n = self.tasks.len();
        let mut queue = ReadyQueue::default();
        for (id, t) in self.tasks.iter().enumerate() {
            if self.missing[id].load(Ordering::Relaxed) == 0 {
                queue.heap.push(Ready {
                    priority: t.priority,
                    seq: id,
                });
            }
        }
        Arc::new(DagShared {
            dag: self,
            queue: Mutex::new(queue),
            cv: Condvar::new(),
            remaining: AtomicUsize::new(n),
            cancel: AtomicBool::new(false),
            stop,
            executors: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            joined: AtomicUsize::new(0),
            retired: AtomicUsize::new(0),
            tasks_run: AtomicUsize::new(0),
            grant_order: Mutex::new(Vec::with_capacity(n)),
            panic_msg: Mutex::new(None),
            arena: Arc::new(PackArena::new()),
            capture_req,
        })
    }
}

/// Sentinel for [`Dag::into_shared`]'s `capture_req`: the run is not a
/// serve request; do not emit task-grant capture records.
pub const NO_REQ: u64 = u64::MAX;

/// Ready-queue entry: max-heap on `(priority, -seq)` so ties pop in
/// submit order — the deterministic grant order of DESIGN.md §17.2.
#[derive(Copy, Clone, Eq, PartialEq)]
struct Ready {
    priority: i32,
    seq: usize,
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Default)]
struct ReadyQueue {
    heap: std::collections::BinaryHeap<Ready>,
}

/// Aggregate execution statistics of one DAG drain.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DagRunStats {
    /// Tasks that actually ran (equals the graph size on a full drain).
    pub tasks_run: usize,
    /// Peak number of concurrently attached executors.
    pub executors_peak: usize,
    /// Executors that attached after the drain started (WS donations).
    pub joined: usize,
    /// Executors that left before the drain finished (lease revocations
    /// honored at a task boundary).
    pub retired: usize,
    /// Whether the drain was cut short by a cancel/stop flag.
    pub cancelled: bool,
    /// Panic message of the first task body that panicked, if any.
    pub panic: Option<String>,
    /// Task ids in grant order (the schedule actually executed; with a
    /// single executor this is exactly the deterministic
    /// `(priority, seq)` order).
    pub grant_order: Vec<usize>,
}

/// Scheduler state shared by every executor of one DAG drain.
///
/// Executors enter through [`DagShared::exec`] (or [`DagSlot::attach`])
/// and leave at a task boundary when the drain completes, the DAG is
/// cancelled, or their lease predicate goes false.
pub struct DagShared {
    dag: Dag,
    queue: Mutex<ReadyQueue>,
    cv: Condvar,
    remaining: AtomicUsize,
    cancel: AtomicBool,
    stop: Option<Arc<AtomicBool>>,
    executors: AtomicUsize,
    peak: AtomicUsize,
    joined: AtomicUsize,
    retired: AtomicUsize,
    tasks_run: AtomicUsize,
    grant_order: Mutex<Vec<usize>>,
    panic_msg: Mutex<Option<String>>,
    arena: Arc<PackArena>,
    capture_req: u64,
}

impl DagShared {
    /// Ask every executor to stop granting new tasks; in-flight tasks
    /// finish. Idempotent.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Whether the drain was cancelled ([`DagShared::cancel`] or the
    /// external stop flag).
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
            || self
                .stop
                .as_ref()
                .is_some_and(|s| s.load(Ordering::Acquire))
    }

    /// Tasks not yet completed.
    pub fn remaining(&self) -> usize {
        self.remaining.load(Ordering::Acquire)
    }

    /// Whether every task has completed.
    pub fn is_drained(&self) -> bool {
        self.remaining() == 0
    }

    /// Currently attached executors.
    pub fn executors(&self) -> usize {
        self.executors.load(Ordering::Acquire)
    }

    /// Run tasks on the calling thread until the drain ends, the DAG is
    /// cancelled, or `lease()` turns false (checked between tasks — the
    /// malleability contract: a revoked executor retires cleanly at a
    /// task boundary). Returns the number of tasks this executor ran.
    ///
    /// `donated` marks executors that joined after the drain started
    /// (counted in [`DagRunStats::joined`]).
    pub fn exec(self: &Arc<Self>, lease: impl Fn() -> bool, donated: bool) -> usize {
        self.enter(donated);
        self.exec_entered(&lease)
    }

    /// [`Self::exec`] for an executor already registered via
    /// [`Self::enter`] (the [`DagSlot::attach`] path, which must
    /// register under the slot lock to not race [`Self::quiesce`]).
    fn exec_entered(self: &Arc<Self>, lease: &dyn Fn() -> bool) -> usize {
        let mut crew = Crew::with_arena(Arc::clone(&self.arena));
        let mut ran = 0usize;
        let mut revoked = false;
        loop {
            if self.is_drained() || self.is_cancelled() {
                break;
            }
            if !lease() {
                revoked = true;
                break;
            }
            let granted = {
                let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
                match q.heap.pop() {
                    Some(r) => {
                        self.grant_order
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .push(r.seq);
                        Some(r.seq)
                    }
                    None => {
                        // Tasks are in flight on other executors; wait
                        // for a release (bounded so lease revocations
                        // and cancels are observed promptly).
                        let _ = self
                            .cv
                            .wait_timeout(q, Duration::from_millis(1))
                            .unwrap_or_else(|e| e.into_inner());
                        None
                    }
                }
            };
            let Some(id) = granted else { continue };
            if capture::active() && self.capture_req != NO_REQ {
                capture::record(
                    DecisionKind::TaskGrant,
                    self.capture_req,
                    id as u64,
                    self.dag.tasks[id].priority as u32 as u64,
                );
            }
            let body = self.dag.tasks[id]
                .run
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take();
            let Some(body) = body else { continue };
            let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| body(&mut crew)));
            match outcome {
                Ok(()) => {
                    ran += 1;
                    self.tasks_run.fetch_add(1, Ordering::AcqRel);
                    for &d in &self.dag.dependents[id] {
                        if self.dag.missing[d].fetch_sub(1, Ordering::AcqRel) == 1 {
                            let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
                            q.heap.push(Ready {
                                priority: self.dag.tasks[d].priority,
                                seq: d,
                            });
                            drop(q);
                            self.cv.notify_all();
                        }
                    }
                    if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        self.cv.notify_all();
                    }
                }
                Err(e) => {
                    let msg = crate::pool::panic_message(e.as_ref());
                    let mut slot = self.panic_msg.lock().unwrap_or_else(|e| e.into_inner());
                    if slot.is_none() {
                        *slot = Some(format!("task `{}` panicked: {msg}", self.dag.tasks[id].name));
                    }
                    drop(slot);
                    self.cancel();
                    break;
                }
            }
        }
        crew.disband();
        if revoked && !(self.is_drained() || self.is_cancelled()) {
            self.retired.fetch_add(1, Ordering::AcqRel);
        }
        self.leave();
        ran
    }

    fn enter(&self, donated: bool) {
        let now = self.executors.fetch_add(1, Ordering::AcqRel) + 1;
        self.peak.fetch_max(now, Ordering::AcqRel);
        if donated {
            self.joined.fetch_add(1, Ordering::AcqRel);
        }
    }

    fn leave(&self) {
        self.executors.fetch_sub(1, Ordering::AcqRel);
        self.cv.notify_all();
    }

    /// Block until no executor remains attached. The leader calls this
    /// (after closing its [`DagSlot`]) before the borrowed matrix the
    /// task bodies captured goes out of scope.
    pub fn quiesce(&self) {
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        while self.executors.load(Ordering::Acquire) > 0 {
            let (qq, _) = self
                .cv
                .wait_timeout(q, Duration::from_millis(1))
                .unwrap_or_else(|e| e.into_inner());
            q = qq;
        }
    }

    /// Statistics of the drain so far (final after [`Self::quiesce`]).
    pub fn stats(&self) -> DagRunStats {
        DagRunStats {
            tasks_run: self.tasks_run.load(Ordering::Acquire),
            executors_peak: self.peak.load(Ordering::Acquire),
            joined: self.joined.load(Ordering::Acquire),
            retired: self.retired.load(Ordering::Acquire),
            cancelled: self.is_cancelled() && !self.is_drained(),
            panic: self
                .panic_msg
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .clone(),
            grant_order: self
                .grant_order
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .clone(),
        }
    }
}

/// A published attachment point for donated executors — the serve
/// layer's Worker-Sharing hook into an in-flight DAG drain
/// (DESIGN.md §17.3). The leader publishes its [`DagShared`] while the
/// drain is running and closes the slot before returning; donors call
/// [`DagSlot::attach`] and run tasks until their lease is revoked.
#[derive(Default)]
pub struct DagSlot {
    inner: Mutex<Option<Arc<DagShared>>>,
}

impl DagSlot {
    /// An empty (closed) slot.
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish an in-flight drain. Called by the leader before it
    /// starts executing.
    pub fn open(&self, shared: &Arc<DagShared>) {
        *self.inner.lock().unwrap_or_else(|e| e.into_inner()) = Some(Arc::clone(shared));
    }

    /// Close the slot; attaches beyond this point find nothing. The
    /// executor count a subsequent [`DagShared::quiesce`] waits on is
    /// exact: attachers increment it under the slot lock, so no executor
    /// can slip in after `close` returns.
    pub fn close(&self) {
        *self.inner.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// Join the published drain as a donated executor, running tasks
    /// until the drain ends or `lease()` turns false. Returns the
    /// number of tasks run, or `None` when no drain is in flight.
    pub fn attach(&self, lease: impl Fn() -> bool) -> Option<usize> {
        let shared = {
            let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            let s = g.as_ref()?;
            // Register under the slot lock so `close` + `quiesce`
            // cannot miss this executor.
            s.enter(true);
            Arc::clone(s)
        };
        Some(shared.exec_entered(&lease))
    }
}

/// Drain `dag` using the calling thread plus every worker of `pool`,
/// polling `cancel` between tasks. The standalone (CLI/bench) execution
/// mode; the serve layer uses [`DagSlot`] + [`DagShared::exec`] instead.
pub fn run_on_pool(
    dag: Dag,
    pool: &Pool,
    cancel: Option<Arc<AtomicBool>>,
    capture_req: u64,
) -> DagRunStats {
    if dag.is_empty() {
        return DagRunStats::default();
    }
    let shared = dag.into_shared(cancel, capture_req);
    let handles: Vec<_> = (0..pool.workers())
        .map(|w| {
            let s = Arc::clone(&shared);
            pool.submit(w, move || {
                s.exec(|| true, false);
            })
        })
        .collect();
    shared.exec(|| true, false);
    for h in handles {
        h.wait();
    }
    shared.quiesce();
    shared.stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn t(i: usize, j: usize) -> Tile {
        Tile { i, j }
    }

    #[test]
    fn grid_spans_and_views_cover_the_matrix() {
        let g = TileGrid::new(50, 80, 16);
        assert_eq!(g.tile_rows(), 4);
        assert_eq!(g.tile_cols(), 5);
        assert_eq!(g.row_span(0), (0, 16));
        assert_eq!(g.row_span(3), (48, 2));
        assert_eq!(g.col_span(4), (64, 16));
        let mut a = crate::matrix::Matrix::zeros(50, 80);
        let v = g.view(a.view_mut(), g.tile(3, 4));
        assert_eq!((v.rows(), v.cols()), (2, 16));
        assert_eq!(g.col_tiles(2, 1).len(), 3);
    }

    /// RAW: a reader depends on the tile's last writer.
    /// WAW: a writer depends on the previous writer.
    /// WAR: a writer barriers behind readers since the last write.
    #[test]
    fn builder_infers_raw_waw_war_edges() {
        let mut b = DagBuilder::new();
        let w0 = b.submit("w0", 0, &[Access::Out(t(0, 0))], |_| {});
        let r1 = b.submit("r1", 0, &[Access::In(t(0, 0))], |_| {});
        let r2 = b.submit("r2", 0, &[Access::In(t(0, 0))], |_| {});
        let w3 = b.submit("w3", 0, &[Access::InOut(t(0, 0))], |_| {});
        let r4 = b.submit("r4", 0, &[Access::In(t(0, 0))], |_| {});
        assert_eq!(b.tasks[w0].deps, Vec::<usize>::new());
        assert_eq!(b.tasks[r1].deps, vec![w0]);
        assert_eq!(b.tasks[r2].deps, vec![w0]);
        // WAW on w0 plus WAR barriers on both readers.
        assert_eq!(b.tasks[w3].deps, vec![w0, r1, r2]);
        // The readers barrier reset: r4 sees only the new writer.
        assert_eq!(b.tasks[r4].deps, vec![w3]);
    }

    #[test]
    fn builder_ignores_self_dependencies() {
        let mut b = DagBuilder::new();
        let w = b.submit("rw", 0, &[Access::In(t(1, 1)), Access::Out(t(1, 1))], |_| {});
        assert_eq!(b.tasks[w].deps, Vec::<usize>::new());
        // And the next writer still barriers behind it.
        let w2 = b.submit("w2", 0, &[Access::Out(t(1, 1))], |_| {});
        assert_eq!(b.tasks[w2].deps, vec![w]);
    }

    #[test]
    fn single_executor_grant_order_is_priority_then_seq() {
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut b = DagBuilder::new();
        for (i, prio) in [(0usize, 0i32), (1, 5), (2, 5), (3, 1)] {
            let o = Arc::clone(&order);
            b.submit(format!("t{i}"), prio, &[], move |_| {
                o.lock().unwrap().push(i);
            });
        }
        let pool = Pool::new(0);
        let stats = run_on_pool(b.build(), &pool, None, NO_REQ);
        assert_eq!(stats.tasks_run, 4);
        // Priority desc, then submit order.
        assert_eq!(*order.lock().unwrap(), vec![1, 2, 3, 0]);
        assert_eq!(stats.grant_order, vec![1, 2, 3, 0]);
    }

    #[test]
    fn dependencies_are_honored_across_executors() {
        // A diamond over one tile column: w -> {r, r} -> w2, run with 3
        // executors, many times to shake interleavings.
        for _ in 0..20 {
            let seen = Arc::new(AtomicUsize::new(0));
            let mut b = DagBuilder::new();
            {
                let s = Arc::clone(&seen);
                b.submit("w", 0, &[Access::Out(t(0, 0))], move |_| {
                    s.fetch_add(1, Ordering::SeqCst);
                });
            }
            for _ in 0..2 {
                let s = Arc::clone(&seen);
                b.submit("r", 0, &[Access::In(t(0, 0))], move |_| {
                    assert!(s.load(Ordering::SeqCst) >= 1);
                    s.fetch_add(10, Ordering::SeqCst);
                });
            }
            let s = Arc::clone(&seen);
            b.submit("w2", 0, &[Access::InOut(t(0, 0))], move |_| {
                assert_eq!(s.load(Ordering::SeqCst), 21);
            });
            let pool = Pool::new(2);
            let stats = run_on_pool(b.build(), &pool, None, NO_REQ);
            assert_eq!(stats.tasks_run, 4);
        }
    }

    #[test]
    fn cancel_stops_granting_at_a_task_boundary() {
        let stop = Arc::new(AtomicBool::new(false));
        let ran = Arc::new(AtomicUsize::new(0));
        let mut b = DagBuilder::new();
        {
            let s = Arc::clone(&stop);
            let r = Arc::clone(&ran);
            b.submit("first", 1, &[Access::Out(t(0, 0))], move |_| {
                r.fetch_add(1, Ordering::SeqCst);
                s.store(true, Ordering::Release);
            });
        }
        for i in 0..4 {
            let r = Arc::clone(&ran);
            b.submit(format!("after{i}"), 0, &[Access::InOut(t(0, 0))], move |_| {
                r.fetch_add(1, Ordering::SeqCst);
            });
        }
        let pool = Pool::new(0);
        let stats = run_on_pool(b.build(), &pool, Some(Arc::clone(&stop)), NO_REQ);
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert!(stats.cancelled);
        assert_eq!(stats.tasks_run, 1);
    }

    #[test]
    fn task_panic_is_contained_and_reported() {
        let mut b = DagBuilder::new();
        b.submit("boom", 0, &[Access::Out(t(0, 0))], |_| panic!("kaboom"));
        b.submit("never", 0, &[Access::In(t(0, 0))], |_| {});
        let pool = Pool::new(1);
        let stats = run_on_pool(b.build(), &pool, None, NO_REQ);
        assert_eq!(stats.tasks_run, 0);
        let msg = stats.panic.expect("panic recorded");
        assert!(msg.contains("boom") && msg.contains("kaboom"), "{msg}");
    }

    #[test]
    fn donated_executor_attaches_and_lease_revocation_retires_it() {
        // A long chain the leader drains slowly; a donor attaches
        // mid-drain, then has its lease revoked and retires with tasks
        // still outstanding.
        let mut b = DagBuilder::new();
        for i in 0..64 {
            b.submit(format!("t{i}"), 0, &[Access::InOut(t(0, 0))], move |_| {
                std::thread::sleep(Duration::from_micros(200));
            });
        }
        let shared = b.build().into_shared(None, NO_REQ);
        let slot = Arc::new(DagSlot::new());
        slot.open(&shared);
        let lease_ok = Arc::new(AtomicBool::new(true));
        let donor = {
            let slot = Arc::clone(&slot);
            let lease = Arc::clone(&lease_ok);
            std::thread::spawn(move || slot.attach(move || lease.load(Ordering::Acquire)))
        };
        // Leader drains; revoke the donor lease partway through.
        let shared2 = Arc::clone(&shared);
        let revoker = std::thread::spawn(move || {
            while shared2.remaining() > 32 {
                std::thread::sleep(Duration::from_micros(100));
            }
            lease_ok.store(false, Ordering::Release);
        });
        shared.exec(|| true, false);
        slot.close();
        shared.quiesce();
        let attached = donor.join().expect("donor thread");
        revoker.join().expect("revoker");
        assert!(attached.is_some(), "donor must find the published drain");
        let stats = shared.stats();
        assert_eq!(stats.tasks_run, 64);
        assert!(stats.joined >= 1, "donor counted: {stats:?}");
        assert!(stats.executors_peak >= 2);
    }

    #[test]
    fn attach_on_closed_slot_is_none() {
        let slot = DagSlot::new();
        assert_eq!(slot.attach(|| true), None);
    }
}
