//! Tiled LU / Cholesky / QR on the tile-DAG runtime — the task-parallel
//! side of the paper's WS+ET-vs-runtime comparison, instantiated through
//! the same [`Factorization`] kernels as the blocked and look-ahead
//! drivers (DESIGN.md §17.4).
//!
//! Per outer panel `k` (block column of width `b_o`) the factorization
//! becomes:
//!
//! - `P[k]` — factorize the panel (priority 1: the critical path),
//!   declaring `InOut` on the panel's tile column;
//! - `U[k,j]` — apply the committed panel to trailing tile column `j`,
//!   declaring `In` on the panel tiles and `InOut` on column `j`'s
//!   tiles.
//!
//! The builder's last-writer tracking then infers exactly the classical
//! tiled-LU dependences — `P[k] ← U[k-1,k]` and
//! `U[k,j] ← {P[k], U[k-1,j]}` — that [`crate::taskrt::lu_os`] spells
//! out by hand.
//!
//! **Bitwise agreement with the blocked driver.** Each task body runs
//! the blocked driver's own kernels on a private sequential crew, and
//! [`Factorization::apply`] is column-split invariant (every output
//! element's reduction is sequential in `k` — the property the
//! look-ahead `P`/`R` split and the `steal_agree` suite already pin
//! down), so splitting one trailing update into per-tile-column tasks
//! reorders nothing within any element's operation chain. LU's lazy
//! left row swaps are deferred to a `k`-ordered epilogue — legal because
//! no DAG task ever touches the already-final columns to their left —
//! which performs the exact swap sequence of the blocked loop.
//! Executor count, donations, and revocations therefore cannot change a
//! bit of the result (`tests/tilert_agree.rs`).
//!
//! **Cancellation and checkpoints.** Panel tasks complete in `k` order
//! (each `P[k]` transitively depends on `P[k-1]`), so committed columns
//! advance exactly as in the blocked driver and the leader fires
//! [`FactorCtl::on_checkpoint`] with the same monotone column counts.
//! A cancel (or a fatal panel-health error) stops task granting at the
//! next task boundary; unlike the blocked driver, already-committed
//! panels may still owe trailing updates to columns right of the
//! factored prefix — the prefix itself is identical.

use super::{Access, DagBuilder, DagRunStats, DagSlot, TileGrid, NO_REQ};
use crate::blis::BlisParams;
use crate::factor::driver::{first_non_finite, panel_health};
use crate::factor::{
    CholFactor, FactorCtl, FactorError, FactorKind, Factorization, FactorOutcome, LuFactor,
    QrFactor,
};
use crate::matrix::{Mat, MatMut};
use crate::pool::{Crew, Pool};
use crate::scalar::Scalar;
use crate::trace::{span, Kind};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Which driver family executes a factorization — the malleable
/// WS+ET look-ahead family (with the blocked driver as its per-request
/// serve face) or the tile-DAG dataflow runtime. The paper's two
/// contenders, selectable per CLI run (`--driver`) and per serve
/// request ([`crate::serve::LuRequest::with_driver`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash, Default)]
pub enum DriverFamily {
    /// Crew-based malleable drivers: the WS+ET look-ahead
    /// ([`crate::factor::factorize_lookahead`]) standalone, the blocked
    /// driver ([`crate::factor::factorize_blocked`]) per serve request.
    #[default]
    Lookahead,
    /// The tile-DAG dataflow runtime ([`factorize_dag`]).
    Dag,
}

impl DriverFamily {
    /// Parse a family name: `lookahead`/`la`/`ws`/`blocked`, or
    /// `dag`/`tile-dag`/`tilert`.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lookahead" | "la" | "ws" | "blocked" => DriverFamily::Lookahead,
            "dag" | "tile-dag" | "tilert" => DriverFamily::Dag,
            _ => return None,
        })
    }

    /// Canonical lowercase name (bench records, trace tags).
    pub fn name(&self) -> &'static str {
        match self {
            DriverFamily::Lookahead => "lookahead",
            DriverFamily::Dag => "dag",
        }
    }

    /// Stable wire code (capture bundles pack it into the Submit
    /// decision; 0 must remain `Lookahead` so pre-§17 bundles replay
    /// unchanged).
    pub fn code(&self) -> u8 {
        match self {
            DriverFamily::Lookahead => 0,
            DriverFamily::Dag => 1,
        }
    }

    /// Inverse of [`Self::code`]; unknown codes fall back to
    /// `Lookahead` (forward-compatible decode).
    pub fn from_code(c: u8) -> Self {
        match c {
            1 => DriverFamily::Dag,
            _ => DriverFamily::Lookahead,
        }
    }
}

/// Where a DAG factorization finds its executors.
enum DagExec<'a> {
    /// The calling thread plus every worker of the pool.
    Pool(&'a Pool),
    /// The calling thread, plus whatever donors [`DagSlot::attach`]
    /// while the drain is in flight (the serve layer's WS path).
    Slot(&'a DagSlot),
}

/// Per-run shared state: panel states handed from `P[k]` to `U[k,·]`
/// and the epilogue, ordered panel progress, and the first
/// health-check failure.
struct DagProgress<St> {
    states: Vec<Mutex<Option<Arc<St>>>>,
    panels_done: AtomicUsize,
    health: Mutex<Option<(FactorError, bool)>>,
}

/// Generic tile-DAG factorization driver: build the task graph, drain
/// it, then run the `k`-ordered epilogue (LU's deferred left swaps +
/// per-panel commits). Returns the accumulated kind output, committed
/// column count, whether a cancel cut the run short, the first typed
/// failure, and the drain statistics.
#[allow(clippy::too_many_arguments)]
fn dag_ctl<S: Scalar, F: Factorization<S>>(
    fk: &F,
    exec: DagExec<'_>,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
    ctl: &FactorCtl,
    capture_req: u64,
) -> (F::Acc, usize, bool, Option<FactorError>, DagRunStats) {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    let bo = bo.max(1);
    let mut acc = F::Acc::default();
    if kmax == 0 {
        // Mirror `taskrt::run`'s empty-graph contract: nothing to do,
        // touch neither the pool nor the scheduler.
        return (acc, 0, false, None, DagRunStats::default());
    }
    if let Some(off) = first_non_finite(&a) {
        return (
            acc,
            0,
            false,
            Some(FactorError::NonFinite { first_offset: off }),
            DagRunStats::default(),
        );
    }
    let npanels = kmax.div_ceil(bo);
    let grid = TileGrid::new(m, n, bo);
    let progress: Arc<DagProgress<F::State>> = Arc::new(DagProgress {
        states: (0..npanels).map(|_| Mutex::new(None)).collect(),
        panels_done: AtomicUsize::new(0),
        health: Mutex::new(None),
    });
    // Fatal-error fuse: a task that detects a run-ending condition trips
    // it, and every executor polls it between tasks.
    let stop = Arc::new(AtomicBool::new(false));

    let mut builder = DagBuilder::new();
    for k in 0..npanels {
        let kl = k * bo;
        let bw = bo.min(kmax - kl);
        let panel_access: Vec<Access> =
            grid.col_tiles(k, k).into_iter().map(Access::InOut).collect();
        {
            let fk = fk.clone();
            let params = *params;
            let prog = Arc::clone(&progress);
            let stop = Arc::clone(&stop);
            let label = match ctl.tag {
                None => format!("dag.panel[{kl}]"),
                Some(tag) => format!("{tag}.panel[{kl}]"),
            };
            builder.submit(format!("P[{k}]"), 1, &panel_access, move |crew| {
                let st = span(Kind::Panel, &label, || {
                    fk.panel(crew, &params, a, kl, bw, bi, false, None)
                });
                debug_assert_eq!(st.k_done, bw);
                if let Some((e, fatal)) = panel_health(fk.kind(), &a, kl, bw) {
                    let mut h = prog.health.lock().unwrap_or_else(|e| e.into_inner());
                    if h.is_none() {
                        *h = Some((e, fatal));
                    }
                    drop(h);
                    if fatal {
                        stop.store(true, Ordering::Release);
                    }
                }
                *prog.states[k].lock().unwrap_or_else(|e| e.into_inner()) =
                    Some(Arc::new(st.state));
                // Panel tasks are chained (P[k] <- U[k-1,k] <- P[k-1]),
                // so this count advances strictly in k order.
                prog.panels_done.store(k + 1, Ordering::Release);
            });
        }
        let jt0 = (kl + bw) / bo;
        for j in jt0..grid.tile_cols() {
            let (jl, jw) = grid.col_span(j);
            let j0 = jl.max(kl + bw);
            let j1 = (jl + jw).min(n);
            if j0 >= j1 {
                continue;
            }
            let mut access: Vec<Access> =
                grid.col_tiles(k, k).into_iter().map(Access::In).collect();
            access.extend(grid.col_tiles(j, k).into_iter().map(Access::InOut));
            let fk = fk.clone();
            let params = *params;
            let prog = Arc::clone(&progress);
            let label = match ctl.tag {
                None => format!("dag.update[{kl}:{j0}]"),
                Some(tag) => format!("{tag}.update[{kl}:{j0}]"),
            };
            builder.submit(format!("U[{k},{j}]"), 0, &access, move |crew| {
                let st = prog.states[k]
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .clone()
                    .expect("panel state ready by dependency");
                span(Kind::Gemm, &label, || {
                    fk.apply(crew, &params, a, kl, bw, &st, j0, j1);
                });
            });
        }
    }

    let shared = builder.build().into_shared(Some(Arc::clone(&stop)), capture_req);

    // The leader's lease doubles as the request-level checkpoint: it is
    // evaluated between the leader's tasks (and every ~1ms while idle),
    // folds the borrowed cancel flag into the shared stop fuse, and
    // fires `on_checkpoint` for each newly completed panel, in order.
    let cancelled_seen = AtomicBool::new(false);
    let fired = Cell::new(0usize);
    let fire_checkpoints = |upto: usize| {
        while fired.get() < upto {
            let p = fired.get() + 1;
            fired.set(p);
            if let Some(cb) = ctl.on_checkpoint {
                cb(if p == npanels { kmax } else { p * bo });
            }
        }
    };
    let leader_lease = || {
        if let Some(c) = ctl.cancel {
            if c.load(Ordering::Acquire) && !stop.load(Ordering::Acquire) {
                cancelled_seen.store(true, Ordering::Release);
                stop.store(true, Ordering::Release);
            }
        }
        fire_checkpoints(progress.panels_done.load(Ordering::Acquire));
        true
    };

    match exec {
        DagExec::Pool(pool) => {
            let handles: Vec<_> = (0..pool.workers())
                .map(|w| {
                    let s = Arc::clone(&shared);
                    pool.submit(w, move || {
                        s.exec(|| true, false);
                    })
                })
                .collect();
            shared.exec(leader_lease, false);
            for h in handles {
                h.wait();
            }
        }
        DagExec::Slot(slot) => {
            slot.open(&shared);
            shared.exec(leader_lease, false);
            slot.close();
        }
    }
    shared.quiesce();
    let stats = shared.stats();

    // A cancel may have landed after the leader's last lease poll.
    if ctl
        .cancel
        .is_some_and(|c| c.load(Ordering::Acquire) && !shared.is_drained())
    {
        cancelled_seen.store(true, Ordering::Release);
    }

    // Epilogue, on the caller: deferred left-applies (LU's lazy row
    // swaps) and commits, in k order — the exact sequence the blocked
    // loop interleaves with its panels.
    let p_done = progress.panels_done.load(Ordering::Acquire).min(npanels);
    let mut crew = Crew::new();
    for k in 0..p_done {
        let kl = k * bo;
        let bw = bo.min(kmax - kl);
        let st = progress.states[k]
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("committed panel state present");
        fk.apply_left(&mut crew, params, a, kl, bw, &st);
        fk.commit(&mut acc, &st, bw);
    }
    fire_checkpoints(p_done);
    let cols_done = if p_done == npanels { kmax } else { p_done * bo };

    let mut error = progress
        .health
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .take()
        .map(|(e, _)| e);
    if let Some(msg) = &stats.panic {
        if error.is_none() {
            error = Some(FactorError::Internal(format!("dag task panicked: {msg}")));
        }
    }
    let cancelled = cancelled_seen.load(Ordering::Acquire);
    (acc, cols_done, cancelled, error, stats)
}

#[allow(clippy::too_many_arguments)]
fn outcome_from<S: Scalar>(
    kind: FactorKind,
    exec: DagExec<'_>,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
    ctl: &FactorCtl,
    capture_req: u64,
) -> FactorOutcome<S> {
    match kind {
        FactorKind::Lu => {
            let (ipiv, cols_done, cancelled, error, _) =
                dag_ctl(&LuFactor, exec, params, a, bo, bi, ctl, capture_req);
            FactorOutcome {
                ipiv,
                tau: Vec::new(),
                cols_done,
                cancelled,
                la_stats: None,
                error,
            }
        }
        FactorKind::Chol => {
            let (_, cols_done, cancelled, error, _) =
                dag_ctl(&CholFactor, exec, params, a, bo, bi, ctl, capture_req);
            FactorOutcome {
                ipiv: Vec::new(),
                tau: Vec::new(),
                cols_done,
                cancelled,
                la_stats: None,
                error,
            }
        }
        FactorKind::Qr => {
            let (tau, cols_done, cancelled, error, _) =
                dag_ctl(&QrFactor, exec, params, a, bo, bi, ctl, capture_req);
            FactorOutcome {
                ipiv: Vec::new(),
                tau,
                cols_done,
                cancelled,
                la_stats: None,
                error,
            }
        }
    }
}

/// Factorize `a` in place on the tile-DAG runtime, dispatching on
/// `kind`, with the calling thread plus every `pool` worker as
/// executors. The task-parallel counterpart of
/// [`crate::factor::factorize_lookahead`]; results are bitwise
/// identical to the blocked driver for any executor count.
pub fn factorize_dag<S: Scalar>(
    kind: FactorKind,
    pool: &Pool,
    params: &BlisParams,
    a: &mut Mat<S>,
    bo: usize,
    bi: usize,
    ctl: &FactorCtl,
) -> FactorOutcome<S> {
    outcome_from(
        kind,
        DagExec::Pool(pool),
        params,
        a.view_mut(),
        bo,
        bi,
        ctl,
        NO_REQ,
    )
}

/// Factorize `a` on the tile-DAG runtime with the calling thread as
/// leader, publishing the drain in `slot` so donated workers can
/// [`DagSlot::attach`] mid-run and retire at task boundaries when their
/// lease is revoked — the serve layer's per-request DAG driver.
/// `capture_req` tags task-grant capture records with the serve
/// request id ([`NO_REQ`] to suppress).
#[allow(clippy::too_many_arguments)]
pub fn factorize_dag_shared<S: Scalar>(
    kind: FactorKind,
    slot: &DagSlot,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
    ctl: &FactorCtl,
    capture_req: u64,
) -> FactorOutcome<S> {
    outcome_from(kind, DagExec::Slot(slot), params, a, bo, bi, ctl, capture_req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::factorize_blocked;
    use crate::matrix::{naive, Matrix};

    fn bits(a: &Matrix) -> Vec<u64> {
        a.data().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn driver_family_parse_and_codes() {
        assert_eq!(DriverFamily::parse("lookahead"), Some(DriverFamily::Lookahead));
        assert_eq!(DriverFamily::parse("blocked"), Some(DriverFamily::Lookahead));
        assert_eq!(DriverFamily::parse("DAG"), Some(DriverFamily::Dag));
        assert_eq!(DriverFamily::parse("tilert"), Some(DriverFamily::Dag));
        assert_eq!(DriverFamily::parse("ompss"), None);
        for f in [DriverFamily::Lookahead, DriverFamily::Dag] {
            assert_eq!(DriverFamily::from_code(f.code()), f);
            assert_eq!(DriverFamily::parse(f.name()), Some(f));
        }
        assert_eq!(DriverFamily::from_code(7), DriverFamily::Lookahead);
    }

    #[test]
    fn dag_lu_matches_blocked_bitwise_and_checkpoints_are_ordered() {
        let n = 56;
        let a0 = Matrix::random(n, n, 21);
        let params = BlisParams::tiny();

        let mut f1 = a0.clone();
        let mut crew = Crew::new();
        let out1 = factorize_blocked(
            FactorKind::Lu,
            &mut crew,
            &params,
            f1.view_mut(),
            16,
            4,
            &FactorCtl::default(),
        );

        let seen = Mutex::new(Vec::new());
        let cb = |k: usize| seen.lock().unwrap().push(k);
        let ctl = FactorCtl {
            on_checkpoint: Some(&cb),
            ..Default::default()
        };
        let pool = Pool::new(2);
        let mut f2 = a0.clone();
        let out2 = factorize_dag(FactorKind::Lu, &pool, &params, &mut f2, 16, 4, &ctl);
        assert_eq!(out2.cols_done, n);
        assert_eq!(out2.error, None);
        assert_eq!(out1.ipiv, out2.ipiv);
        assert_eq!(bits(&f1), bits(&f2));
        assert_eq!(*seen.lock().unwrap(), vec![16, 32, 48, 56]);
    }

    #[test]
    fn dag_handles_wide_and_tall_shapes() {
        let params = BlisParams::tiny();
        let pool = Pool::new(1);
        for (m, n) in [(40usize, 72usize), (72, 40), (50, 50)] {
            let a0 = Matrix::random(m, n, (m * 31 + n) as u64);
            let mut f1 = a0.clone();
            let mut crew = Crew::new();
            let out1 = factorize_blocked(
                FactorKind::Lu,
                &mut crew,
                &params,
                f1.view_mut(),
                16,
                4,
                &FactorCtl::default(),
            );
            let mut f2 = a0.clone();
            let out2 = factorize_dag(
                FactorKind::Lu,
                &pool,
                &params,
                &mut f2,
                16,
                4,
                &FactorCtl::default(),
            );
            assert_eq!(out1.ipiv, out2.ipiv, "{m}x{n}");
            assert_eq!(bits(&f1), bits(&f2), "{m}x{n}");
            assert_eq!(out2.cols_done, m.min(n));
        }
    }

    #[test]
    fn dag_chol_and_qr_reconstruct() {
        let params = BlisParams::tiny();
        let pool = Pool::new(2);
        let n = 48;

        let a0 = Matrix::random_spd(n, 5);
        let mut f = a0.clone();
        let out = factorize_dag(
            FactorKind::Chol,
            &pool,
            &params,
            &mut f,
            16,
            4,
            &FactorCtl::default(),
        );
        assert_eq!(out.cols_done, n);
        assert_eq!(out.error, None);
        let r = naive::chol_residual(&a0, &f);
        assert!(r < 1e-12, "chol residual {r}");

        let a0 = Matrix::random(n, n, 6);
        let mut f = a0.clone();
        let out = factorize_dag(
            FactorKind::Qr,
            &pool,
            &params,
            &mut f,
            16,
            4,
            &FactorCtl::default(),
        );
        assert_eq!(out.cols_done, n);
        assert_eq!(out.tau.len(), n);
        let r = naive::qr_residual(&a0, &f, &out.tau);
        assert!(r < 1e-11, "qr residual {r}");
    }

    #[test]
    fn dag_cancel_leaves_clean_prefix() {
        let n = 64;
        let params = BlisParams::tiny();
        // Leader-only: the cancel lands deterministically between the
        // leader's task grants (with extra executors the drain could
        // finish before the leader's next lease poll observes it).
        let pool = Pool::new(0);
        let a0 = Matrix::random(n, n, 11);

        let cancel = AtomicBool::new(false);
        let cb = |k: usize| {
            if k >= 32 {
                cancel.store(true, Ordering::Release);
            }
        };
        let ctl = FactorCtl {
            cancel: Some(&cancel),
            on_checkpoint: Some(&cb),
            ..Default::default()
        };
        let mut f = a0.clone();
        let out = factorize_dag(FactorKind::Lu, &pool, &params, &mut f, 16, 4, &ctl);
        assert!(out.cancelled);
        assert!(out.cols_done >= 32 && out.cols_done < n, "{}", out.cols_done);
        assert_eq!(out.ipiv.len(), out.cols_done);

        // Reference: a blocked run cancelled after the same committed
        // column count. Both runs then committed the same panels and
        // applied exactly those panels' left swaps, so the factored
        // prefix (columns and pivots) must agree bit for bit.
        let stop_at = out.cols_done;
        let cancel2 = AtomicBool::new(false);
        let cb2 = |k: usize| {
            if k >= stop_at {
                cancel2.store(true, Ordering::Release);
            }
        };
        let ctl2 = FactorCtl {
            cancel: Some(&cancel2),
            on_checkpoint: Some(&cb2),
            ..Default::default()
        };
        let mut g = a0.clone();
        let mut crew = Crew::new();
        let ref_out = factorize_blocked(
            FactorKind::Lu,
            &mut crew,
            &params,
            g.view_mut(),
            16,
            4,
            &ctl2,
        );
        assert_eq!(ref_out.cols_done, stop_at);
        assert_eq!(out.ipiv, ref_out.ipiv);
        for j in 0..stop_at {
            for i in 0..n {
                assert_eq!(
                    f.data()[j * n + i].to_bits(),
                    g.data()[j * n + i].to_bits(),
                    "col {j} row {i}"
                );
            }
        }
    }

    #[test]
    fn dag_empty_matrix_is_a_noop() {
        let params = BlisParams::tiny();
        let pool = Pool::new(0);
        let mut a = Matrix::zeros(0, 0);
        let out = factorize_dag(
            FactorKind::Lu,
            &pool,
            &params,
            &mut a,
            16,
            4,
            &FactorCtl::default(),
        );
        assert_eq!(out.cols_done, 0);
        assert!(!out.cancelled);
        assert_eq!(out.error, None);
    }

    #[test]
    fn dag_reports_nonfinite_input() {
        let params = BlisParams::tiny();
        let pool = Pool::new(0);
        let mut a = Matrix::random(16, 16, 3);
        a.data_mut()[5] = f64::NAN;
        let out = factorize_dag(
            FactorKind::Lu,
            &pool,
            &params,
            &mut a,
            8,
            4,
            &FactorCtl::default(),
        );
        assert!(matches!(out.error, Some(FactorError::NonFinite { .. })));
        assert_eq!(out.cols_done, 0);
    }
}
